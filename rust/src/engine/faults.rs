//! Deterministic, seedable fault injection for the serving path.
//!
//! A [`FaultPlan`] threads through `Engine::builder().faults(..)` (and
//! from there into the network front-end) and perturbs the pipeline in
//! exactly reproducible ways: injected executor errors, caught worker
//! panics, and artificial per-stage latency. Every decision is a pure
//! function of `(plan seed, fault domain, event id)` — rerunning the
//! same plan over the same query stream fires the same faults, so the
//! robustness tests and the CI soak are deterministic, not
//! probabilistic hope.
//!
//! The plan deliberately lives at the engine layer (not the socket
//! layer): the serving front-end reuses the same plan for its
//! frame-level faults (dropped responses), so one `--fault-*` flag set
//! drives the whole stack.

use std::time::Duration;

/// Fault domains — mixed into the hash so the same event id draws
/// independent decisions per fault class.
pub mod domain {
    pub const EXEC_ERROR: u64 = 1;
    pub const EXEC_PANIC: u64 = 2;
    pub const DROP_RESPONSE: u64 = 3;
    pub const CLIENT_GARBLE: u64 = 4;
    pub const WORKER_KILL: u64 = 5;
}

/// A deterministic fault-injection plan. The default plan is inert
/// (all rates zero, no delays) and adds no work to the hot path.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed for every fault decision.
    pub seed: u64,
    /// Probability a query's execution is replaced by
    /// [`EngineError::Injected`](super::EngineError::Injected).
    pub exec_error: f64,
    /// Probability a query's worker panics mid-execution (the panic is
    /// caught and becomes a per-query
    /// [`EngineError::WorkerPanic`](super::EngineError::WorkerPanic)).
    pub exec_panic: f64,
    /// Artificial latency added once per planned group (plan stage).
    pub plan_delay: Duration,
    /// Artificial latency added to each query's execution.
    pub exec_delay: Duration,
    /// Probability the serving front-end silently drops a response
    /// frame (the connection stays up; the client times out).
    pub drop_response: f64,
    /// Probability a cluster worker dies (simulated process kill) right
    /// before it would process a job, keyed by the job's admission
    /// sequence. The supervisor detects the dead worker, restarts it on
    /// the same cache shard, and replays the orphaned job — replayed
    /// jobs are kill-exempt so a poisonous job cannot crash-loop.
    pub worker_kill: f64,
}

impl FaultPlan {
    /// An inert plan (no faults, no delays).
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// `true` when any fault or delay is configured.
    pub fn is_active(&self) -> bool {
        self.exec_error > 0.0
            || self.exec_panic > 0.0
            || self.drop_response > 0.0
            || self.worker_kill > 0.0
            || !self.plan_delay.is_zero()
            || !self.exec_delay.is_zero()
    }

    /// Deterministic uniform draw in `[0, 1)` for `(domain, id)` —
    /// splitmix64 over the mixed key.
    pub fn roll(&self, domain: u64, id: u64) -> f64 {
        let mut z = self
            .seed
            .wrapping_add(domain.wrapping_mul(0x9E3779B97F4A7C15))
            .wrapping_add(id.wrapping_mul(0xBF58476D1CE4E5B9));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Should a fault with probability `rate` fire for `(domain, id)`?
    pub fn fire(&self, rate: f64, domain: u64, id: u64) -> bool {
        rate > 0.0 && self.roll(domain, id) < rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        let p = FaultPlan::none();
        assert!(!p.is_active());
        for id in 0..1000 {
            assert!(!p.fire(p.exec_error, domain::EXEC_ERROR, id));
        }
    }

    #[test]
    fn decisions_are_deterministic_and_domain_independent() {
        let p = FaultPlan {
            seed: 42,
            exec_error: 0.5,
            ..FaultPlan::default()
        };
        let q = p.clone();
        let mut differs = false;
        for id in 0..256 {
            assert_eq!(
                p.fire(0.5, domain::EXEC_ERROR, id),
                q.fire(0.5, domain::EXEC_ERROR, id),
                "same plan, same decision"
            );
            if p.fire(0.5, domain::EXEC_ERROR, id) != p.fire(0.5, domain::EXEC_PANIC, id) {
                differs = true;
            }
        }
        assert!(differs, "domains must draw independently");
    }

    #[test]
    fn rates_are_respected_roughly() {
        let p = FaultPlan {
            seed: 7,
            ..FaultPlan::default()
        };
        let n = 10_000u64;
        let hits = (0..n)
            .filter(|&id| p.fire(0.1, domain::EXEC_ERROR, id))
            .count();
        let frac = hits as f64 / n as f64;
        assert!((0.05..0.15).contains(&frac), "got {frac}");
        // rolls are uniform-ish: never all-zero, never all-one
        assert!((0..n).any(|id| p.roll(domain::EXEC_ERROR, id) > 0.9));
        assert!((0..n).any(|id| p.roll(domain::EXEC_ERROR, id) < 0.1));
    }

    #[test]
    fn activity_detection() {
        assert!(FaultPlan {
            exec_panic: 0.01,
            ..FaultPlan::default()
        }
        .is_active());
        assert!(FaultPlan {
            exec_delay: Duration::from_micros(1),
            ..FaultPlan::default()
        }
        .is_active());
        assert!(FaultPlan {
            worker_kill: 0.2,
            ..FaultPlan::default()
        }
        .is_active());
        assert!(!FaultPlan {
            seed: 99,
            ..FaultPlan::default()
        }
        .is_active());
    }
}
