//! The engine's typed request/response pair.
//!
//! A [`Query`] is one GEMM request: the workload shape plus everything
//! that parameterizes its trip through the plan → schedule → execute
//! pipeline (objective, operand seed, execute/verify flags). A
//! [`Response`] is the full answer: the chosen accelerator and mapping,
//! per-pool scores, execution/verification status, latency, and (on
//! request) the computed result matrix.

use std::time::Instant;

use crate::cost::Objective;
use crate::flash::EvaluatedMapping;
use crate::workloads::Gemm;

/// Default operand seed — kept identical to the historical
/// `GemmService` constant so shimmed traffic reproduces bit-for-bit.
pub const DEFAULT_SEED: u64 = 0x5EED;

/// One GEMM request through the engine pipeline.
#[derive(Debug, Clone)]
pub struct Query {
    /// The workload shape (the name rides along into the response; only
    /// M/N/K participate in planning and coalescing).
    pub workload: Gemm,
    /// Selection objective; `None` uses the engine's default.
    pub objective: Option<Objective>,
    /// Seed for deterministic operand generation. The seed travels with
    /// the query, so a query's numeric result is independent of where it
    /// sits in the submission window.
    pub seed: u64,
    /// Execute numerically (subject to the engine's `max_exec_dim`
    /// cap); `false` returns a plan-only response.
    pub execute: bool,
    /// Verify the executed result against a reference GEMM.
    pub verify: bool,
    /// Return the computed `M×N` result matrix in the response.
    pub return_result: bool,
    /// Serve-by deadline. The engine re-checks it immediately before
    /// execution: expired queries are shed with
    /// [`EngineError::DeadlineExceeded`](super::EngineError::DeadlineExceeded),
    /// never run. `None` means no deadline.
    pub deadline: Option<Instant>,
}

impl Query {
    /// A query with the default pipeline flags: execute, don't verify,
    /// don't return the result matrix, engine-default objective.
    pub fn new(workload: Gemm) -> Self {
        Query {
            workload,
            objective: None,
            seed: DEFAULT_SEED,
            execute: true,
            verify: false,
            return_result: false,
            deadline: None,
        }
    }

    /// Select by this objective instead of the engine default.
    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = Some(objective);
        self
    }

    /// Seed the deterministic operand generator.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Toggle numeric execution (plan-only when `false`).
    pub fn execute(mut self, execute: bool) -> Self {
        self.execute = execute;
        self
    }

    /// Toggle verification against the reference GEMM.
    pub fn verify(mut self, verify: bool) -> Self {
        self.verify = verify;
        self
    }

    /// Toggle returning the computed result matrix.
    pub fn return_result(mut self, return_result: bool) -> Self {
        self.return_result = return_result;
        self
    }

    /// Shed this query (instead of executing it) once `deadline` has
    /// passed.
    pub fn deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// `true` when the query carries a deadline that has passed.
    pub fn deadline_expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

impl From<Gemm> for Query {
    fn from(workload: Gemm) -> Self {
        Query::new(workload)
    }
}

/// The engine's answer to one [`Query`].
#[derive(Debug, Clone)]
pub struct Response {
    /// The requesting workload (name preserved).
    pub workload: Gemm,
    /// The objective the query was planned under.
    pub objective: Objective,
    /// Index of the chosen accelerator in the engine's pool.
    pub accelerator_idx: usize,
    /// The winning mapping with its projected cost.
    pub mapping: EvaluatedMapping,
    /// Per-accelerator objective scores, pool order (`None` =
    /// infeasible on that pool member).
    pub scores: Vec<Option<f64>>,
    /// Whether the plan was served entirely from the mapping cache.
    pub cache_hit: bool,
    /// Whether the GEMM was executed numerically.
    pub executed: bool,
    /// Verification outcome (`None` when not requested or not executed).
    pub verified: Option<bool>,
    /// Wall-clock latency attributed to this query (operand generation +
    /// execution + verification; 0 for plan-only responses).
    pub latency_us: u64,
    /// The computed row-major `M×N` result, when
    /// [`Query::return_result`] was set and execution happened.
    pub result: Option<Vec<f32>>,
}

impl Response {
    /// Name of the winning mapping.
    pub fn mapping_name(&self) -> String {
        self.mapping.mapping.name()
    }

    /// Projected runtime of the winning mapping in milliseconds.
    pub fn projected_ms(&self) -> f64 {
        self.mapping.cost.runtime_ms()
    }

    /// The chosen accelerator's objective score.
    pub fn score(&self) -> Option<f64> {
        self.scores.get(self.accelerator_idx).copied().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_builder_chains() {
        let q = Query::new(Gemm::new("q", 8, 8, 8))
            .objective(Objective::Energy)
            .seed(7)
            .execute(false)
            .verify(true)
            .return_result(true);
        assert_eq!(q.objective, Some(Objective::Energy));
        assert_eq!(q.seed, 7);
        assert!(!q.execute && q.verify && q.return_result);
    }

    #[test]
    fn query_defaults_match_service_conventions() {
        let q: Query = Gemm::new("q", 8, 8, 8).into();
        assert_eq!(q.seed, DEFAULT_SEED);
        assert!(q.execute && !q.verify && !q.return_result);
        assert!(q.objective.is_none());
        assert!(q.deadline.is_none());
    }

    #[test]
    fn deadline_expiry() {
        let now = Instant::now();
        let q = Query::new(Gemm::new("q", 8, 8, 8));
        assert!(!q.deadline_expired(now), "no deadline never expires");
        let q = q.deadline(now + std::time::Duration::from_secs(3600));
        assert!(!q.deadline_expired(now));
        assert!(q.deadline_expired(now + std::time::Duration::from_secs(7200)));
        // a deadline exactly at `now` counts as expired
        let q = Query::new(Gemm::new("q", 8, 8, 8)).deadline(now);
        assert!(q.deadline_expired(now));
    }
}
