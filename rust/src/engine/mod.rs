//! The unified serving engine: one typed Query → Plan → Response
//! pipeline behind an [`Engine`] facade.
//!
//! The paper's framework is a single pipeline — workload + accelerator →
//! optimized mapping → cost/execution — and this module is its one
//! front door. An [`Engine`] owns the accelerator pool, the execution
//! [`Runtime`](crate::runtime::Runtime), a shared
//! [`MappingCache`](crate::flash::MappingCache), and cumulative
//! [`ServiceMetrics`](crate::coordinator::ServiceMetrics); a typed
//! [`Query`] flows through three stages:
//!
//! 1. **Plan** — objective-aware mapping selection over the pool,
//!    cache-first: one FLASH search per distinct
//!    (shape, style, config, objective), ever, shared across every
//!    engine holding the same cache.
//! 2. **Schedule** — queries coalesce by (shape, objective) across the
//!    *whole* submission window, not just consecutive runs: a shuffled
//!    trace plans and executes exactly like the sorted one, and each
//!    query's operand seed travels with it so results are independent
//!    of submission order.
//! 3. **Execute** — each group fans over rayon through the packed-panel
//!    engine ([`PackedGemm`](crate::runtime::PackedGemm)) on the native
//!    backend, or per-request through the tile-artifact path under
//!    `--features pjrt`.
//!
//! The legacy entry points — `GemmService::serve`, `Router::route`,
//! `coordinator::search_grid`, and the CLI `serve`/`search` subcommands
//! — are thin (deprecated) adapters over this facade.
//!
//! ```
//! use flash_gemm::prelude::*;
//!
//! let mut engine = Engine::builder()
//!     .accelerator(Accelerator::of_style(Style::Nvdla, HwConfig::edge()))
//!     .build()
//!     .expect("non-empty pool");
//! let response = engine
//!     .query(Query::new(Gemm::new("demo", 64, 48, 32)).verify(true))
//!     .expect("servable");
//! assert!(response.executed);
//! assert_eq!(response.verified, Some(true));
//! ```

mod error;
mod facade;
mod faults;
mod query;

pub use error::EngineError;
pub use facade::{
    close, operands, reference_gemm, Engine, EngineBuilder, EngineReport, EngineWindow,
    GraphPlan, GraphReport, GridResult, Plan,
};
pub use faults::{domain as fault_domain, FaultPlan};
pub use query::{Query, Response, DEFAULT_SEED};
