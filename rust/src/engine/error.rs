//! Typed errors for the serving path.
//!
//! The engine's batch pipeline never aborts a whole submission window
//! because one query failed: every query gets its own
//! `Result<Response, EngineError>` (see `Engine::try_run`), and the
//! serving front-end maps each variant onto a wire-level error kind.
//! `EngineError` is `Clone` because a group-level failure (e.g. an
//! infeasible plan) fans out to every member of the coalesced group.

/// Why one query could not be served. One query's error never affects
/// the other queries in its coalesced batch.
#[derive(Debug, Clone, thiserror::Error)]
pub enum EngineError {
    /// No accelerator in the pool has a feasible mapping for the shape.
    #[error("no accelerator in the pool can run {workload}: {reason}")]
    Infeasible { workload: String, reason: String },
    /// The shape is degenerate or its element/MAC counts overflow.
    #[error("invalid shape for {workload}: {detail}")]
    DimensionOverflow { workload: String, detail: String },
    /// The query's deadline expired before `stage` ran; the work was
    /// shed, never executed.
    #[error("deadline exceeded before {stage}")]
    DeadlineExceeded { stage: &'static str },
    /// A fault-plan-injected executor error (testing only).
    #[error("injected fault: {0}")]
    Injected(String),
    /// A worker panicked mid-execution; the panic was caught and only
    /// this query failed.
    #[error("worker panic: {0}")]
    WorkerPanic(String),
    /// The execution backend failed (missing artifact, packing error).
    #[error("execution failed: {0}")]
    Exec(String),
}

impl EngineError {
    /// Stable machine-readable kind string (the wire protocol's error
    /// taxonomy uses these verbatim).
    pub fn kind(&self) -> &'static str {
        match self {
            EngineError::Infeasible { .. } => "infeasible",
            EngineError::DimensionOverflow { .. } => "unknown_shape",
            EngineError::DeadlineExceeded { .. } => "deadline_exceeded",
            EngineError::Injected(_) => "injected_fault",
            EngineError::WorkerPanic(_) => "worker_panic",
            EngineError::Exec(_) => "exec_failed",
        }
    }

    /// `true` for load-shedding outcomes (the work was intentionally
    /// not performed), as opposed to genuine failures.
    pub fn is_shed(&self) -> bool {
        matches!(self, EngineError::DeadlineExceeded { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_shed_classification() {
        let cases: Vec<(EngineError, &str, bool)> = vec![
            (
                EngineError::Infeasible {
                    workload: "w".into(),
                    reason: "r".into(),
                },
                "infeasible",
                false,
            ),
            (
                EngineError::DimensionOverflow {
                    workload: "w".into(),
                    detail: "zero".into(),
                },
                "unknown_shape",
                false,
            ),
            (
                EngineError::DeadlineExceeded { stage: "execute" },
                "deadline_exceeded",
                true,
            ),
            (EngineError::Injected("x".into()), "injected_fault", false),
            (EngineError::WorkerPanic("p".into()), "worker_panic", false),
            (EngineError::Exec("e".into()), "exec_failed", false),
        ];
        for (e, kind, shed) in cases {
            assert_eq!(e.kind(), kind);
            assert_eq!(e.is_shed(), shed, "{e}");
            // every variant displays its payload
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn converts_into_anyhow() {
        let e = EngineError::DeadlineExceeded { stage: "execute" };
        let a: anyhow::Error = e.into();
        assert!(a.to_string().contains("deadline"));
    }
}
