//! The `Engine` facade: builder, planning, window scheduling, and
//! execution fan-out.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use rayon::prelude::*;

use crate::arch::{Accelerator, ArchSpec, HwConfig};
use crate::coordinator::ServiceMetrics;
use crate::cost::Objective;
use crate::flash::{self, EvaluatedMapping, MappingCache, SearchOpts, SearchResult};
use crate::graph::{self, ChainOutput, ChainPlan, GraphPlanCache, OpGraph};
use crate::runtime::{Manifest, PackedGemm, Runtime, TiledExecutor};
use crate::workloads::Gemm;

use super::error::EngineError;
use super::faults::{domain, FaultPlan};
use super::query::{Query, Response};

/// Stage-1 output: the objective-aware selection for one shape over the
/// engine's accelerator pool.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Index of the winning accelerator in the pool.
    pub accelerator_idx: usize,
    /// The winning mapping with its projected cost.
    pub best: EvaluatedMapping,
    /// Per-accelerator objective scores, pool order (`None` =
    /// infeasible on that pool member).
    pub scores: Vec<Option<f64>>,
    /// `true` when every pool member was served from the shared mapping
    /// cache — no FLASH search ran for this plan.
    pub cache_hit: bool,
}

/// Stage-1 output for an operator graph: the joint chain selection
/// over the engine's accelerator pool (the graph sibling of [`Plan`]).
#[derive(Debug, Clone)]
pub struct GraphPlan {
    /// Index of the winning accelerator in the pool.
    pub accelerator_idx: usize,
    /// The winning joint chain plan (shared with the cache).
    pub plan: Arc<ChainPlan>,
    /// Per-accelerator joint scores, pool order (`None` = infeasible on
    /// that pool member).
    pub scores: Vec<Option<f64>>,
    /// `true` when every pool member was served from the shared
    /// [`GraphPlanCache`] — no frontier search ran for this plan.
    pub cache_hit: bool,
}

/// What one [`Engine::run_graph`] produced: the joint plan, the pinned
/// per-stage execution tiles, and the executed chain output.
#[derive(Debug, Clone)]
pub struct GraphReport {
    pub graph_name: String,
    pub plan: GraphPlan,
    /// Per-stage execution tile (shared across each fusable segment).
    pub tiles: Vec<usize>,
    pub output: ChainOutput,
    pub latency_us: u64,
}

/// One cell of a (accelerator × workload) planning grid.
#[derive(Debug)]
pub struct GridResult {
    pub accelerator: Accelerator,
    pub workload: Gemm,
    pub result: anyhow::Result<SearchResult>,
}

/// What one [`Engine::run`] window produced: responses in submission
/// order plus the window's own metrics (also merged into the engine's
/// cumulative [`Engine::metrics`]).
#[derive(Debug)]
pub struct EngineReport {
    pub responses: Vec<Response>,
    pub metrics: ServiceMetrics,
}

/// What one [`Engine::try_run`] window produced: a per-query outcome
/// (in submission order — one query's failure never disturbs the
/// others) plus the window's own metrics. This is the serving-path
/// sibling of [`EngineReport`].
#[derive(Debug)]
pub struct EngineWindow {
    /// One outcome per submitted query, submission order.
    pub outcomes: Vec<Result<Response, EngineError>>,
    /// The window's metrics (already merged into the engine's
    /// cumulative [`Engine::metrics`]).
    pub metrics: ServiceMetrics,
}

impl EngineWindow {
    /// Number of successfully answered queries.
    pub fn ok_count(&self) -> usize {
        self.outcomes.iter().filter(|o| o.is_ok()).count()
    }

    /// Number of failed/shed queries.
    pub fn err_count(&self) -> usize {
        self.outcomes.len() - self.ok_count()
    }
}

/// Builder for [`Engine`] — see the module docs for the pipeline it
/// configures. (Not `Debug`: it may hold a [`Runtime`], which wraps
/// backend state without a `Debug` impl.)
pub struct EngineBuilder {
    pool: Vec<Accelerator>,
    runtime: Option<Runtime>,
    objective: Objective,
    cache: Option<Arc<MappingCache>>,
    graph_cache: Option<Arc<GraphPlanCache>>,
    max_exec_dim: u64,
    tile: u64,
    faults: FaultPlan,
}

impl EngineBuilder {
    /// Attach one accelerator to the pool.
    pub fn accelerator(mut self, accelerator: Accelerator) -> Self {
        self.pool.push(accelerator);
        self
    }

    /// Replace the whole accelerator pool.
    pub fn pool(mut self, pool: Vec<Accelerator>) -> Self {
        self.pool = pool;
        self
    }

    /// Attach an accelerator described by an [`ArchSpec`] (validated
    /// first). A spec without its own `[hardware]` table runs under the
    /// Table 4 edge config; bind a different one with
    /// [`Accelerator::from_spec`] + [`EngineBuilder::accelerator`].
    pub fn arch(mut self, spec: ArchSpec) -> Result<Self> {
        spec.validate()?;
        self.pool
            .push(Accelerator::from_spec(spec, HwConfig::edge()));
        Ok(self)
    }

    /// Attach an accelerator loaded from a `.toml` / `.json` spec file —
    /// the "bring your own accelerator" entry point:
    ///
    /// ```no_run
    /// # fn main() -> anyhow::Result<()> {
    /// use flash_gemm::engine::Engine;
    /// let engine = Engine::builder()
    ///     .arch_file("specs/os_mesh.toml")?
    ///     .build()?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn arch_file(self, path: impl AsRef<std::path::Path>) -> Result<Self> {
        self.arch(ArchSpec::load(path)?)
    }

    /// Execution backend (default: the native interpreter over a
    /// synthetic 16/32/64 tile manifest).
    pub fn runtime(mut self, runtime: Runtime) -> Self {
        self.runtime = Some(runtime);
        self
    }

    /// Default selection objective for queries that don't set their own.
    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Share a mapping cache with other engines / services — warm shapes
    /// hit regardless of which instance searched them first.
    pub fn shared_cache(mut self, cache: Arc<MappingCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Share a graph-plan cache with other engines — a chain jointly
    /// planned by any sharing instance is a hit for all of them.
    pub fn shared_graph_cache(mut self, cache: Arc<GraphPlanCache>) -> Self {
        self.graph_cache = Some(cache);
        self
    }

    /// Cap on M/N/K for numeric execution (larger queries get plan-only
    /// responses). Default 512.
    pub fn max_exec_dim(mut self, max_exec_dim: u64) -> Self {
        self.max_exec_dim = max_exec_dim;
        self
    }

    /// Force a specific tile artifact (0 ⇒ auto per shape).
    pub fn tile(mut self, tile: u64) -> Self {
        self.tile = tile;
        self
    }

    /// Thread a deterministic [`FaultPlan`] through the pipeline
    /// (testing/soak only; the default plan is inert).
    pub fn faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Build the engine; fails on an empty accelerator pool.
    pub fn build(self) -> Result<Engine> {
        if self.pool.is_empty() {
            bail!("engine needs a non-empty accelerator pool");
        }
        Ok(Engine {
            pool: self.pool,
            runtime: self
                .runtime
                .unwrap_or_else(|| Runtime::native(Manifest::synthetic(&[16, 32, 64]))),
            objective: self.objective,
            cache: self.cache.unwrap_or_default(),
            graph_cache: self.graph_cache.unwrap_or_default(),
            max_exec_dim: self.max_exec_dim,
            tile: self.tile,
            faults: self.faults,
            metrics: ServiceMetrics::default(),
        })
    }
}

/// Everything one execution group needs besides the engine itself: the
/// group's plan, objective, tile size, and member query indices.
struct GroupRun<'a> {
    plan: &'a Plan,
    objective: Objective,
    tile: u64,
    members: &'a [usize],
}

/// The unified serving facade: one accelerator pool, one execution
/// runtime, one shared mapping cache, one metrics ledger — and one typed
/// [`Query`] → [`Plan`] → [`Response`] pipeline over them.
pub struct Engine {
    pool: Vec<Accelerator>,
    runtime: Runtime,
    objective: Objective,
    cache: Arc<MappingCache>,
    graph_cache: Arc<GraphPlanCache>,
    max_exec_dim: u64,
    tile: u64,
    faults: FaultPlan,
    metrics: ServiceMetrics,
}

impl Engine {
    /// Start building an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder {
            pool: Vec::new(),
            runtime: None,
            objective: Objective::Runtime,
            cache: None,
            graph_cache: None,
            max_exec_dim: 512,
            tile: 0,
            faults: FaultPlan::none(),
        }
    }

    /// The accelerator pool, in planning order.
    pub fn pool(&self) -> &[Accelerator] {
        &self.pool
    }

    /// The execution backend.
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// The shared mapping cache (e.g. to pre-warm, share, or inspect).
    pub fn cache(&self) -> &Arc<MappingCache> {
        &self.cache
    }

    /// The shared graph-plan cache.
    pub fn graph_cache(&self) -> &Arc<GraphPlanCache> {
        &self.graph_cache
    }

    /// Cumulative metrics across every window this engine served.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// The default selection objective.
    pub fn objective(&self) -> Objective {
        self.objective
    }

    /// The active fault-injection plan (inert by default).
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// Swap the fault-injection plan on a built engine (the serving
    /// front-end uses this to arm/disarm faults without rebuilding).
    pub fn set_faults(&mut self, faults: FaultPlan) {
        self.faults = faults;
    }

    /// **Stage 1 — plan.** Objective-aware mapping selection over the
    /// pool, cache-first: each pool member's best mapping comes from the
    /// shared [`MappingCache`] (one FLASH search per distinct
    /// (shape, style, config, objective), ever), and the accelerator
    /// with the lowest objective score wins. Always returns per-pool
    /// scores.
    pub fn plan(&self, workload: &Gemm, objective: Objective) -> Result<Plan> {
        let mut scores = Vec::with_capacity(self.pool.len());
        let mut searches = 0usize;
        let mut last_err = None;
        let mut best: Option<(usize, EvaluatedMapping, f64)> = None;
        for (i, acc) in self.pool.iter().enumerate() {
            // a pool member already known infeasible for this key is a
            // cached answer, not a search — score None and move on
            if self.cache.is_infeasible(acc, workload, objective) {
                scores.push(None);
                continue;
            }
            match self.cache.get_or_search_with(acc, workload, objective) {
                Ok((e, hit)) => {
                    if !hit {
                        searches += 1;
                    }
                    let s = objective.score(&e.cost);
                    scores.push(Some(s));
                    let better = match &best {
                        Some((_, _, bs)) => s < *bs,
                        None => true,
                    };
                    if better {
                        best = Some((i, e, s));
                    }
                }
                Err(e) => {
                    searches += 1;
                    last_err = Some(e);
                    scores.push(None);
                }
            }
        }
        let Some((accelerator_idx, best, _)) = best else {
            let msg = format!("no accelerator in the pool can run {workload}");
            return Err(match last_err {
                Some(e) => e.context(msg),
                None => anyhow!(msg),
            });
        };
        Ok(Plan {
            accelerator_idx,
            best,
            scores,
            cache_hit: searches == 0,
        })
    }

    /// [`Engine::plan`] with a typed, cloneable error: infeasibility
    /// becomes [`EngineError::Infeasible`], so a group-level planning
    /// failure can fan out to every member of the coalesced group
    /// without aborting the window.
    pub fn plan_checked(
        &self,
        workload: &Gemm,
        objective: Objective,
    ) -> Result<Plan, EngineError> {
        self.plan(workload, objective).map_err(|e| {
            let root = e.root_cause().to_string();
            let reason = if root.contains("no accelerator in the pool") {
                "every pool member is infeasible for this shape".to_string()
            } else {
                root
            };
            EngineError::Infeasible {
                workload: workload.to_string(),
                reason,
            }
        })
    }

    /// Fan a full (pool × workloads) planning grid over rayon — the
    /// §5.4 evaluation sweep. Results preserve pool-major, workload-
    /// minor order and carry the complete [`SearchResult`] statistics;
    /// searches run under the engine's default objective, so every
    /// winning mapping warms the shared cache for the lookups
    /// [`Engine::plan`]/[`Engine::run`] will actually make.
    pub fn plan_grid(&self, workloads: &[Gemm]) -> Vec<GridResult> {
        let pairs: Vec<(&Accelerator, &Gemm)> = self
            .pool
            .iter()
            .flat_map(|a| workloads.iter().map(move |w| (a, w)))
            .collect();
        // capture only the (Sync) cache, not the whole engine — the
        // runtime never participates in planning
        let cache = &self.cache;
        let objective = self.objective;
        pairs
            .par_iter()
            .map(|&(acc, wl)| {
                let result = flash::search_with(
                    acc,
                    wl,
                    &SearchOpts {
                        objective,
                        ..Default::default()
                    },
                );
                if let Ok(r) = &result {
                    cache.insert_with(acc, wl, objective, r.best.clone());
                }
                GridResult {
                    accelerator: acc.clone(),
                    workload: wl.clone(),
                    result,
                }
            })
            .collect()
    }

    /// Full FLASH search (with candidate/pruning statistics) on one pool
    /// member, warming the shared cache with the winner. The plan path
    /// ([`Engine::plan`]) is cache-first and cheaper; this is for
    /// report-style consumers that need the whole [`SearchResult`].
    pub fn search_detailed(
        &self,
        accelerator_idx: usize,
        workload: &Gemm,
        objective: Objective,
    ) -> Result<SearchResult> {
        let acc = self.pool.get(accelerator_idx).ok_or_else(|| {
            anyhow!(
                "accelerator index {accelerator_idx} out of range (pool of {})",
                self.pool.len()
            )
        })?;
        let r = flash::search_with(
            acc,
            workload,
            &SearchOpts {
                objective,
                ..Default::default()
            },
        )?;
        self.cache.insert_with(acc, workload, objective, r.best.clone());
        Ok(r)
    }

    /// Jointly plan an operator graph over the pool, cache-first: each
    /// pool member's [`ChainPlan`] comes from the shared
    /// [`GraphPlanCache`] — one joint search per distinct
    /// (graph, architecture, objective) key, ever — and the member with
    /// the lowest joint score wins.
    pub fn plan_graph(
        &self,
        graph: &OpGraph,
        objective: Objective,
    ) -> Result<GraphPlan, EngineError> {
        let infeasible = |reason: String| EngineError::Infeasible {
            workload: graph.name.clone(),
            reason,
        };
        let chain = graph.lower().map_err(|e| infeasible(e.to_string()))?;
        let mut scores = Vec::with_capacity(self.pool.len());
        let mut searches = 0usize;
        let mut last_err = None;
        let mut best: Option<(usize, Arc<ChainPlan>)> = None;
        for (i, acc) in self.pool.iter().enumerate() {
            if self.graph_cache.is_infeasible(acc, &chain, objective) {
                scores.push(None);
                continue;
            }
            match self.graph_cache.get_or_plan(acc, &chain, objective) {
                Ok((plan, hit)) => {
                    if !hit {
                        searches += 1;
                    }
                    scores.push(Some(plan.joint_score));
                    let better = match &best {
                        Some((_, b)) => plan.joint_score < b.joint_score,
                        None => true,
                    };
                    if better {
                        best = Some((i, plan));
                    }
                }
                Err(e) => {
                    searches += 1;
                    last_err = Some(e);
                    scores.push(None);
                }
            }
        }
        let Some((accelerator_idx, plan)) = best else {
            return Err(infeasible(match last_err {
                Some(e) => e.root_cause().to_string(),
                None => "every pool member is infeasible for this chain".into(),
            }));
        };
        Ok(GraphPlan {
            accelerator_idx,
            plan,
            scores,
            cache_hit: searches == 0,
        })
    }

    /// Plan and execute an operator graph end to end on the fused
    /// packed path: epilogues applied in-tile, direct edges handing
    /// packed output tiles straight to the consumer's `A` panels.
    /// Operand data is derived deterministically from `seed`.
    pub fn run_graph(&self, graph: &OpGraph, seed: u64) -> Result<GraphReport, EngineError> {
        self.run_graph_inner(graph, seed, true)
    }

    /// The unfused node-by-node reference for [`Engine::run_graph`]:
    /// same plan, same data, same tiles — pack / execute / unpack per
    /// stage with a matrix epilogue pass. Bit-identical output by
    /// construction (the fusion-correctness tests pin this).
    pub fn run_graph_unfused(
        &self,
        graph: &OpGraph,
        seed: u64,
    ) -> Result<GraphReport, EngineError> {
        self.run_graph_inner(graph, seed, false)
    }

    fn run_graph_inner(
        &self,
        graph: &OpGraph,
        seed: u64,
        fused: bool,
    ) -> Result<GraphReport, EngineError> {
        let started = Instant::now();
        let plan = self.plan_graph(graph, self.objective)?;
        let chain = graph.lower().map_err(|e| EngineError::Infeasible {
            workload: graph.name.clone(),
            reason: e.to_string(),
        })?;
        let data = graph::chain_data(&chain, seed);
        let tiles = graph::segment_tiles(
            &chain,
            &self.runtime.manifest().tile_sizes(),
            (self.tile > 0).then_some(self.tile as usize),
        );
        let orders = graph::plan_orders(&plan.plan);
        let run = if fused {
            graph::run_fused
        } else {
            graph::run_unfused
        };
        let output = run(&chain, &data, &orders, &tiles)
            .map_err(|e| EngineError::Exec(e.to_string()))?;
        Ok(GraphReport {
            graph_name: graph.name.clone(),
            plan,
            tiles,
            output,
            latency_us: started.elapsed().as_micros() as u64,
        })
    }

    /// Serve one query (a one-element [`Engine::run`] window).
    pub fn query(&mut self, query: Query) -> Result<Response> {
        let mut report = self.run(std::slice::from_ref(&query))?;
        Ok(report.responses.pop().expect("one response per query"))
    }

    /// Serve a whole submission window through the three-stage pipeline.
    ///
    /// * **Plan** — one objective-aware, cache-first selection per
    ///   distinct (shape, objective) in the window.
    /// * **Schedule** — queries coalesce across the *entire* window (not
    ///   just consecutive runs): every query of a shape joins one group
    ///   regardless of its position, so a shuffled trace plans and
    ///   executes exactly like the sorted one.
    /// * **Execute** — each group fans over rayon through the packed-
    ///   panel engine (native backend) or runs per-request through the
    ///   tile-artifact path, with per-query seeds, verification, and
    ///   latency accounting.
    ///
    /// Responses come back in submission order; the window's metrics are
    /// returned and merged into [`Engine::metrics`].
    ///
    /// This is the strict variant: the first per-query failure aborts
    /// the whole window with an error (and the window's metrics are
    /// discarded, as before). The serving path uses
    /// [`Engine::try_run`], which keeps going and returns one `Result`
    /// per query.
    pub fn run(&mut self, queries: &[Query]) -> Result<EngineReport> {
        let EngineWindow { outcomes, metrics } = self.run_window(queries);
        let mut responses = Vec::with_capacity(outcomes.len());
        for outcome in outcomes {
            responses.push(outcome?);
        }
        self.metrics.merge(&metrics);
        Ok(EngineReport { responses, metrics })
    }

    /// Serve a submission window with per-query fault isolation: every
    /// query gets its own `Result<Response, EngineError>`, and one
    /// query's failure — infeasible shape, dimension overflow, injected
    /// fault, caught worker panic, expired deadline — never aborts its
    /// coalesced batch; the other members still plan, execute, and
    /// verify exactly as they would alone. Deadlines are re-checked
    /// immediately before execution (and again between execution
    /// chunks), so expired work is shed, never run. The window's
    /// metrics are merged into [`Engine::metrics`].
    pub fn try_run(&mut self, queries: &[Query]) -> EngineWindow {
        let window = self.run_window(queries);
        self.metrics.merge(&window.metrics);
        window
    }

    fn run_window(&mut self, queries: &[Query]) -> EngineWindow {
        let mut window = ServiceMetrics::default();
        let mut outcomes: Vec<Option<Result<Response, EngineError>>> =
            queries.iter().map(|_| None).collect();

        // stage 0 — validate: degenerate or overflowing shapes become
        // typed errors here, before they can panic arithmetic downstream
        for (qi, q) in queries.iter().enumerate() {
            if let Err(e) = validate_shape(&q.workload) {
                window.errors += 1;
                outcomes[qi] = Some(Err(e));
            }
        }

        // stage 2 — schedule: coalesce by (shape, objective) across the
        // whole window, groups in first-appearance order
        let mut group_of: HashMap<(u64, u64, u64, Objective), usize> = HashMap::new();
        let mut groups: Vec<(Objective, Vec<usize>)> = Vec::new();
        for (qi, q) in queries.iter().enumerate() {
            if outcomes[qi].is_some() {
                continue;
            }
            let objective = q.objective.unwrap_or(self.objective);
            let key = (q.workload.m, q.workload.n, q.workload.k, objective);
            let gi = *group_of.entry(key).or_insert_with(|| {
                groups.push((objective, Vec::new()));
                groups.len() - 1
            });
            groups[gi].1.push(qi);
        }

        for (objective, members) in &groups {
            window.batches += 1;
            let shape = &queries[members[0]].workload;

            // stage 1 — plan, cache-first; an infeasible shape fails
            // only its own group, the window keeps going
            let t0 = Instant::now();
            let plan = match self.plan_checked(shape, *objective) {
                Ok(plan) => plan,
                Err(e) => {
                    for &qi in members {
                        window.errors += 1;
                        outcomes[qi] = Some(Err(e.clone()));
                    }
                    continue;
                }
            };
            if plan.cache_hit {
                window.mapping_cache_hits += 1;
            } else {
                window.mapping_cache_misses += 1;
                window.search_time += t0.elapsed();
            }
            if !self.faults.plan_delay.is_zero() {
                std::thread::sleep(self.faults.plan_delay);
            }

            // deadline check: shed members that expired while queued
            let now = Instant::now();
            let (live, expired): (Vec<usize>, Vec<usize>) = members
                .iter()
                .copied()
                .partition(|&qi| !queries[qi].deadline_expired(now));
            for qi in expired {
                window.shed_deadline += 1;
                outcomes[qi] = Some(Err(EngineError::DeadlineExceeded { stage: "execute" }));
            }

            let can_exec = shape.m.max(shape.n).max(shape.k) <= self.max_exec_dim;
            let (exec, skip): (Vec<usize>, Vec<usize>) = live
                .into_iter()
                .partition(|&qi| can_exec && queries[qi].execute);

            for qi in skip {
                window.latency.record(Duration::ZERO);
                window.requests += 1;
                outcomes[qi] = Some(Ok(Self::plan_only_response(&plan, *objective, &queries[qi])));
            }

            if !exec.is_empty() {
                let tile = if self.tile > 0 {
                    self.tile
                } else {
                    TiledExecutor::auto_tile(&self.runtime, shape)
                };
                let group = GroupRun {
                    plan: &plan,
                    objective: *objective,
                    tile,
                    members: &exec,
                };
                if self.runtime.is_native() {
                    self.exec_packed(&group, queries, &mut window, &mut outcomes);
                } else {
                    self.exec_serial(&group, queries, &mut window, &mut outcomes);
                }
            }
        }

        // invariant: every query got an outcome above; a typed error
        // (not a panic) guards the serving path even if it ever breaks
        let outcomes = outcomes
            .into_iter()
            .map(|o| {
                o.unwrap_or_else(|| {
                    Err(EngineError::Exec("internal: query left unanswered".into()))
                })
            })
            .collect();
        EngineWindow {
            outcomes,
            metrics: window,
        }
    }

    fn plan_only_response(plan: &Plan, objective: Objective, q: &Query) -> Response {
        Response {
            workload: q.workload.clone(),
            objective,
            accelerator_idx: plan.accelerator_idx,
            mapping: plan.best.clone(),
            scores: plan.scores.clone(),
            cache_hit: plan.cache_hit,
            executed: false,
            verified: None,
            latency_us: 0,
            result: None,
        }
    }

    /// **Stage 3 — execute** one group through the packed parallel
    /// engine. Operand generation, execution, and verification each fan
    /// over rayon; `exec_time` accounts the execution phase's wall clock
    /// only. The group is processed in bounded chunks (a few queries per
    /// worker thread) so memory stays O(chunk), not O(group). Every
    /// query is individually fallible: injected faults and worker
    /// panics are caught per query, and the rest of the chunk finishes
    /// untouched.
    fn exec_packed(
        &mut self,
        group: &GroupRun,
        queries: &[Query],
        window: &mut ServiceMetrics,
        outcomes: &mut [Option<Result<Response, EngineError>>],
    ) {
        let shape = &queries[group.members[0]].workload;
        // tile artifact must exist, exactly as the per-tile path demands
        let prepared = self
            .runtime
            .warm(&format!("gemm_tile_{}", group.tile))
            .and_then(|_| {
                PackedGemm::new(shape, group.tile as usize, group.plan.best.mapping.inter_order)
            });
        let pg = match prepared {
            Ok(pg) => pg,
            Err(e) => {
                // backend preparation failed: the group fails with a
                // typed error, the rest of the window keeps going
                for &qi in group.members {
                    window.errors += 1;
                    outcomes[qi] = Some(Err(EngineError::Exec(format!("{e:#}"))));
                }
                return;
            }
        };
        let calls = pg.tile_calls();
        let chunk_len = rayon::current_num_threads().max(1) * 4;
        let faults = self.faults.clone();

        for chunk in group.members.chunks(chunk_len) {
            // deadlines re-checked per chunk: work that expired while
            // earlier chunks executed is shed, never run
            let now = Instant::now();
            let (live, expired): (Vec<usize>, Vec<usize>) = chunk
                .iter()
                .copied()
                .partition(|&qi| !queries[qi].deadline_expired(now));
            for qi in expired {
                window.shed_deadline += 1;
                outcomes[qi] = Some(Err(EngineError::DeadlineExceeded { stage: "execute" }));
            }
            if live.is_empty() {
                continue;
            }

            // phase 1: deterministic operands from each query's own seed
            let inputs: Vec<(Vec<f32>, Vec<f32>, Duration)> = live
                .par_iter()
                .map(|&qi| {
                    let t0 = Instant::now();
                    let q = &queries[qi];
                    let (a, b) = operands(&q.workload, q.seed);
                    (a, b, t0.elapsed())
                })
                .collect();

            // phase 2: packed-panel parallel execution, per-query
            // fallible — injected faults fire deterministically off the
            // query seed, and panics are caught so one poisoned query
            // never takes down its batchmates
            let te0 = Instant::now();
            let mut execs: Vec<Result<(Vec<f32>, Duration), EngineError>> = inputs
                .par_iter()
                .zip(&live)
                .map(|((a, b, _), &qi)| {
                    let q = &queries[qi];
                    if faults.fire(faults.exec_error, domain::EXEC_ERROR, q.seed) {
                        return Err(EngineError::Injected(format!(
                            "executor error for seed {:#x}",
                            q.seed
                        )));
                    }
                    let panic_now = faults.fire(faults.exec_panic, domain::EXEC_PANIC, q.seed);
                    let t0 = Instant::now();
                    let run = catch_unwind(AssertUnwindSafe(|| {
                        if panic_now {
                            panic!("injected worker panic");
                        }
                        if !faults.exec_delay.is_zero() {
                            std::thread::sleep(faults.exec_delay);
                        }
                        pg.run(a, b)
                    }));
                    match run {
                        Ok(Ok(c)) => Ok((c, t0.elapsed())),
                        Ok(Err(e)) => Err(EngineError::Exec(format!("{e:#}"))),
                        Err(payload) => Err(EngineError::WorkerPanic(panic_message(&*payload))),
                    }
                })
                .collect();
            window.exec_time += te0.elapsed();

            // phase 3: per-query verification against the reference GEMM
            let checks: Vec<(Option<bool>, Duration)> = inputs
                .par_iter()
                .zip(&execs)
                .enumerate()
                .map(|(ci, ((a, b, _), exec))| {
                    let q = &queries[live[ci]];
                    match exec {
                        Ok((c, _)) if q.verify => {
                            let t0 = Instant::now();
                            let r = reference_gemm(&q.workload, a, b);
                            (Some(close(c, &r)), t0.elapsed())
                        }
                        _ => (None, Duration::ZERO),
                    }
                })
                .collect();

            let ok_runs = execs.iter().filter(|e| e.is_ok()).count() as u64;
            self.runtime.note_executions(calls * ok_runs);
            for (ci, &qi) in live.iter().enumerate() {
                let q = &queries[qi];
                match &mut execs[ci] {
                    Ok((c, exec_dt)) => {
                        let latency = inputs[ci].2 + *exec_dt + checks[ci].1;
                        window.latency.record(latency);
                        window.requests += 1;
                        window.macs_executed += q.workload.macs();
                        window.tile_calls += calls;
                        let result = q.return_result.then(|| std::mem::take(c));
                        outcomes[qi] = Some(Ok(Response {
                            workload: q.workload.clone(),
                            objective: group.objective,
                            accelerator_idx: group.plan.accelerator_idx,
                            mapping: group.plan.best.clone(),
                            scores: group.plan.scores.clone(),
                            cache_hit: group.plan.cache_hit,
                            executed: true,
                            verified: checks[ci].0,
                            latency_us: latency.as_micros() as u64,
                            result,
                        }));
                    }
                    Err(e) => {
                        window.errors += 1;
                        outcomes[qi] = Some(Err(e.clone()));
                    }
                }
            }
        }
    }

    /// **Stage 3 — execute** one group query-by-query through the
    /// per-tile artifact path (`--features pjrt`, or any non-native
    /// backend): the real compiled kernel runs once per grid point.
    /// Per-query fallible, same fault semantics as the packed path.
    fn exec_serial(
        &mut self,
        group: &GroupRun,
        queries: &[Query],
        window: &mut ServiceMetrics,
        outcomes: &mut [Option<Result<Response, EngineError>>],
    ) {
        let faults = self.faults.clone();
        for &qi in group.members {
            let q = &queries[qi];
            if q.deadline_expired(Instant::now()) {
                window.shed_deadline += 1;
                outcomes[qi] = Some(Err(EngineError::DeadlineExceeded { stage: "execute" }));
                continue;
            }
            if faults.fire(faults.exec_error, domain::EXEC_ERROR, q.seed) {
                window.errors += 1;
                outcomes[qi] = Some(Err(EngineError::Injected(format!(
                    "executor error for seed {:#x}",
                    q.seed
                ))));
                continue;
            }
            let t0 = Instant::now();
            let (a, b) = operands(&q.workload, q.seed);
            let te0 = Instant::now();
            let panic_now = faults.fire(faults.exec_panic, domain::EXEC_PANIC, q.seed);
            let run = catch_unwind(AssertUnwindSafe(|| {
                if panic_now {
                    panic!("injected worker panic");
                }
                if !faults.exec_delay.is_zero() {
                    std::thread::sleep(faults.exec_delay);
                }
                let mut exec = TiledExecutor::new(
                    &mut self.runtime,
                    group.tile as usize,
                    group.plan.best.mapping.inter_order,
                )?;
                let c = exec.gemm(&q.workload, &a, &b)?;
                Ok::<_, anyhow::Error>((c, exec.tile_calls))
            }));
            let (c, tile_calls) = match run {
                Ok(Ok(v)) => v,
                Ok(Err(e)) => {
                    window.errors += 1;
                    outcomes[qi] = Some(Err(EngineError::Exec(format!("{e:#}"))));
                    continue;
                }
                Err(payload) => {
                    window.errors += 1;
                    outcomes[qi] = Some(Err(EngineError::WorkerPanic(panic_message(&*payload))));
                    continue;
                }
            };
            window.tile_calls += tile_calls;
            window.exec_time += te0.elapsed();
            window.macs_executed += q.workload.macs();
            let verified = q
                .verify
                .then(|| close(&c, &reference_gemm(&q.workload, &a, &b)));
            let latency = t0.elapsed();
            window.latency.record(latency);
            window.requests += 1;
            outcomes[qi] = Some(Ok(Response {
                workload: q.workload.clone(),
                objective: group.objective,
                accelerator_idx: group.plan.accelerator_idx,
                mapping: group.plan.best.clone(),
                scores: group.plan.scores.clone(),
                cache_hit: group.plan.cache_hit,
                executed: true,
                verified,
                latency_us: latency.as_micros() as u64,
                result: q.return_result.then_some(c),
            }));
        }
    }
}

/// Pre-flight shape validation: zero dimensions and element/MAC counts
/// that would overflow `u64` become typed errors instead of downstream
/// arithmetic panics.
fn validate_shape(wl: &Gemm) -> Result<(), EngineError> {
    let err = |detail: &str| EngineError::DimensionOverflow {
        workload: wl.to_string(),
        detail: detail.into(),
    };
    if wl.m == 0 || wl.n == 0 || wl.k == 0 {
        return Err(err("dimensions must be nonzero"));
    }
    let products = [
        wl.m.checked_mul(wl.k),
        wl.k.checked_mul(wl.n),
        wl.m.checked_mul(wl.n).and_then(|mn| mn.checked_mul(wl.k)),
    ];
    if products.iter().any(|p| p.is_none()) {
        return Err(err("element/MAC count overflows u64"));
    }
    Ok(())
}

/// Render a caught panic payload as a message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).into()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}

/// Deterministic operand data for a query (xorshift64*; the exact
/// generator the serving path has always used, so shimmed traffic is
/// bit-identical).
pub fn operands(wl: &Gemm, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut state = seed.max(1);
    let mut gen = |n: u64| -> Vec<f32> {
        (0..n)
            .map(|_| {
                state ^= state >> 12;
                state ^= state << 25;
                state ^= state >> 27;
                ((state.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32)
                    - 0.5
            })
            .collect()
    };
    (gen(wl.m * wl.k), gen(wl.k * wl.n))
}

/// Reference row-major GEMM for verification.
pub fn reference_gemm(wl: &Gemm, a: &[f32], b: &[f32]) -> Vec<f32> {
    let (m, n, k) = (wl.m as usize, wl.n as usize, wl.k as usize);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            let crow = &mut c[i * n..(i + 1) * n];
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Element-wise closeness check against a reference result.
pub fn close(c: &[f32], r: &[f32]) -> bool {
    c.iter()
        .zip(r)
        .all(|(x, y)| (x - y).abs() <= 1e-3 * (1.0 + y.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{HwConfig, Style};

    fn native_engine() -> Engine {
        Engine::builder()
            .accelerator(Accelerator::of_style(Style::Maeri, HwConfig::edge()))
            .runtime(Runtime::native(Manifest::synthetic(&[16, 32])))
            .max_exec_dim(128)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_rejects_empty_pool() {
        assert!(Engine::builder().build().is_err());
    }

    #[test]
    fn builder_defaults() {
        let engine = Engine::builder()
            .accelerator(Accelerator::of_style(Style::Nvdla, HwConfig::edge()))
            .build()
            .unwrap();
        assert_eq!(engine.objective(), Objective::Runtime);
        assert_eq!(engine.pool().len(), 1);
        assert!(engine.runtime().is_native());
        assert!(engine.cache().is_empty());
        assert_eq!(engine.metrics().requests, 0);
    }

    #[test]
    fn plan_scores_every_pool_member_and_is_cache_first() {
        let engine = Engine::builder()
            .pool(Accelerator::all_styles(&HwConfig::edge()))
            .build()
            .unwrap();
        let wl = Gemm::new("sq", 64, 64, 64);
        let first = engine.plan(&wl, Objective::Runtime).unwrap();
        assert_eq!(first.scores.len(), engine.pool().len());
        assert!(!first.cache_hit);
        let chosen = first.scores[first.accelerator_idx].unwrap();
        for s in first.scores.iter().flatten() {
            assert!(chosen <= *s + 1e-12);
        }
        // a second plan for the same (shape, objective) runs no search
        let second = engine.plan(&wl, Objective::Runtime).unwrap();
        assert!(second.cache_hit);
        assert_eq!(second.accelerator_idx, first.accelerator_idx);
        assert_eq!(second.best.mapping, first.best.mapping);
        assert_eq!(second.scores, first.scores);
        // a different objective is its own cache entry
        let energy = engine.plan(&wl, Objective::Energy).unwrap();
        assert!(!energy.cache_hit);
    }

    #[test]
    fn query_executes_verifies_and_returns_result() {
        let mut engine = native_engine();
        let wl = Gemm::new("q", 48, 40, 24);
        let r = engine
            .query(Query::new(wl.clone()).verify(true).return_result(true))
            .unwrap();
        assert!(r.executed);
        assert_eq!(r.verified, Some(true));
        let c = r.result.as_ref().expect("result requested");
        assert_eq!(c.len(), (wl.m * wl.n) as usize);
        assert!(r.projected_ms() > 0.0);
        assert!(r.score().is_some());
        assert_eq!(engine.metrics().requests, 1);
    }

    #[test]
    fn window_coalesces_across_gaps() {
        let mut engine = native_engine();
        let queries = vec![
            Query::new(Gemm::new("a1", 64, 64, 64)),
            Query::new(Gemm::new("b", 32, 96, 48)),
            Query::new(Gemm::new("a2", 64, 64, 64)), // same shape as a1
        ];
        let rep = engine.run(&queries).unwrap();
        // a1 and a2 coalesce into one group despite b between them
        assert_eq!(rep.metrics.batches, 2);
        assert_eq!(rep.metrics.mapping_cache_misses, 2);
        assert_eq!(rep.metrics.mapping_cache_hits, 0);
        assert_eq!(rep.metrics.requests, 3);
        // responses stay in submission order
        let names: Vec<&str> = rep
            .responses
            .iter()
            .map(|r| r.workload.name.as_str())
            .collect();
        assert_eq!(names, ["a1", "b", "a2"]);
        // a rerun of the same window is all cache hits
        let rep2 = engine.run(&queries).unwrap();
        assert_eq!(rep2.metrics.mapping_cache_hits, 2);
        assert_eq!(rep2.metrics.mapping_cache_misses, 0);
        // cumulative engine metrics cover both windows
        assert_eq!(engine.metrics().requests, 6);
        assert_eq!(engine.metrics().batches, 4);
    }

    #[test]
    fn execute_flag_and_exec_cap_give_plan_only_responses() {
        let mut engine = native_engine();
        let rep = engine
            .run(&[
                Query::new(Gemm::new("plan-only", 64, 64, 64)).execute(false),
                Query::new(Gemm::new("too-big", 8192, 64, 64)),
            ])
            .unwrap();
        for r in &rep.responses {
            assert!(!r.executed, "{}", r.workload.name);
            assert!(r.verified.is_none());
            assert!(r.result.is_none());
            assert!(r.projected_ms() > 0.0);
        }
    }

    #[test]
    fn per_query_objectives_split_groups() {
        let mut engine = native_engine();
        let wl = Gemm::new("sq", 64, 64, 64);
        let rep = engine
            .run(&[
                Query::new(wl.clone()),
                Query::new(wl.clone()).objective(Objective::Energy),
                Query::new(wl.clone()),
            ])
            .unwrap();
        // same shape, two objectives ⇒ two groups, two searches
        assert_eq!(rep.metrics.batches, 2);
        assert_eq!(rep.metrics.mapping_cache_misses, 2);
        assert_eq!(rep.responses[0].objective, Objective::Runtime);
        assert_eq!(rep.responses[1].objective, Objective::Energy);
        // the energy plan can never project more energy than the runtime plan
        assert!(
            rep.responses[1].mapping.cost.energy_j
                <= rep.responses[0].mapping.cost.energy_j + 1e-12
        );
    }

    #[test]
    fn plan_grid_covers_pool_major_order() {
        let engine = Engine::builder()
            .pool(Accelerator::all_styles(&HwConfig::edge()))
            .build()
            .unwrap();
        let wls = vec![Gemm::new("a", 64, 64, 64), Gemm::new("b", 8, 128, 32)];
        let grid = engine.plan_grid(&wls);
        assert_eq!(grid.len(), 10);
        assert_eq!(grid[0].workload.name, "a");
        assert_eq!(grid[1].workload.name, "b");
        assert_eq!(grid[0].accelerator.name(), engine.pool()[0].name());
        for cell in &grid {
            assert!(cell.result.is_ok(), "{}", cell.accelerator);
        }
        // the grid warmed the cache: planning those shapes is now free
        let plan = engine.plan(&wls[0], Objective::Runtime).unwrap();
        assert!(plan.cache_hit);
    }

    #[test]
    fn builder_accepts_specs_and_spec_files() {
        use crate::arch::Style;
        let mut spec = Style::ShiDianNao.spec();
        spec.name = "custom-sdn".into();
        spec.hardware = Some(HwConfig::tiny());
        // invalid specs are rejected at build time, not search time
        let mut broken = spec.clone();
        broken.dataflow.inter_orders.clear();
        assert!(Engine::builder().arch(broken).is_err());

        let path = std::env::temp_dir().join("flash_gemm_builder_spec.toml");
        std::fs::write(&path, spec.to_toml()).unwrap();
        let mut engine = Engine::builder()
            .arch(spec.clone())
            .unwrap()
            .arch_file(&path)
            .unwrap()
            .build()
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(engine.pool().len(), 2);
        assert_eq!(engine.pool()[0].name(), "custom-sdn");
        // both pool members are the same description: same identity hash
        assert_eq!(
            engine.pool()[0].spec_hash(),
            engine.pool()[1].spec_hash()
        );
        // the spec's own [hardware] table binds the config
        assert_eq!(engine.pool()[0].config, HwConfig::tiny());
        let r = engine
            .query(Query::new(Gemm::new("q", 24, 16, 12)).verify(true))
            .unwrap();
        assert!(r.executed);
        assert_eq!(r.verified, Some(true));
    }

    #[test]
    fn try_run_isolates_per_query_failures() {
        let mut engine = native_engine();
        let window = engine.try_run(&[
            Query::new(Gemm::new("ok", 32, 32, 32)).verify(true),
            Query::new(Gemm::new("zero", 0, 32, 32)),
        ]);
        assert_eq!(window.ok_count(), 1);
        assert_eq!(window.err_count(), 1);
        let ok = window.outcomes[0].as_ref().unwrap();
        assert!(ok.executed);
        assert_eq!(ok.verified, Some(true));
        let err = window.outcomes[1].as_ref().unwrap_err();
        assert_eq!(err.kind(), "unknown_shape");
        assert_eq!(window.metrics.errors, 1);
        assert_eq!(window.metrics.requests, 1);
        // try_run merges its window into the cumulative ledger
        assert_eq!(engine.metrics().errors, 1);
        assert_eq!(engine.metrics().requests, 1);
    }

    #[test]
    fn run_surfaces_first_failure_and_discards_window_metrics() {
        let mut engine = native_engine();
        let err = engine
            .run(&[Query::new(Gemm::new("zero", 8, 0, 8))])
            .unwrap_err();
        assert!(err.to_string().contains("invalid shape"), "{err:#}");
        assert_eq!(engine.metrics().requests, 0);
        assert_eq!(engine.metrics().errors, 0);
    }

    #[test]
    fn overflowing_shapes_are_typed_errors_not_panics() {
        let mut engine = native_engine();
        let window = engine.try_run(&[Query::new(Gemm::new("huge", u64::MAX, 2, 2))]);
        let err = window.outcomes[0].as_ref().unwrap_err();
        assert_eq!(err.kind(), "unknown_shape");
        assert!(err.to_string().contains("overflow"), "{err}");
    }

    #[test]
    fn injected_faults_fail_only_their_queries() {
        let plan = FaultPlan {
            seed: 9,
            exec_error: 0.5,
            ..FaultPlan::default()
        };
        let fire = (0..64u64)
            .find(|&s| plan.fire(plan.exec_error, domain::EXEC_ERROR, s))
            .unwrap();
        let calm = (0..64u64)
            .find(|&s| !plan.fire(plan.exec_error, domain::EXEC_ERROR, s))
            .unwrap();
        let mut faulty = Engine::builder()
            .accelerator(Accelerator::of_style(Style::Maeri, HwConfig::edge()))
            .runtime(Runtime::native(Manifest::synthetic(&[16, 32])))
            .max_exec_dim(128)
            .faults(plan)
            .build()
            .unwrap();
        assert!(faulty.faults().is_active());
        let wl = Gemm::new("w", 32, 32, 32);
        let queries = vec![
            Query::new(wl.clone()).seed(fire).return_result(true),
            Query::new(wl.clone()).seed(calm).return_result(true),
        ];
        let window = faulty.try_run(&queries);
        let err = window.outcomes[0].as_ref().unwrap_err();
        assert_eq!(err.kind(), "injected_fault");
        let survivor = window.outcomes[1].as_ref().unwrap();
        assert!(survivor.executed);
        // the surviving batchmate is bit-identical to a clean engine
        let mut clean = native_engine();
        let clean_rep = clean.run(std::slice::from_ref(&queries[1])).unwrap();
        assert_eq!(survivor.result, clean_rep.responses[0].result);
        // and the whole thing replays deterministically
        let replay = faulty.try_run(&queries);
        assert_eq!(replay.outcomes[0].as_ref().unwrap_err().kind(), "injected_fault");
        assert_eq!(
            replay.outcomes[1].as_ref().unwrap().result,
            survivor.result
        );
    }

    #[test]
    fn worker_panics_are_caught_per_query() {
        let mut engine = Engine::builder()
            .accelerator(Accelerator::of_style(Style::Maeri, HwConfig::edge()))
            .runtime(Runtime::native(Manifest::synthetic(&[16, 32])))
            .max_exec_dim(128)
            .faults(FaultPlan {
                seed: 3,
                exec_panic: 1.0,
                ..FaultPlan::default()
            })
            .build()
            .unwrap();
        let window = engine.try_run(&[Query::new(Gemm::new("p", 32, 32, 32))]);
        let err = window.outcomes[0].as_ref().unwrap_err();
        assert_eq!(err.kind(), "worker_panic");
        assert!(err.to_string().contains("injected worker panic"), "{err}");
        assert_eq!(window.metrics.errors, 1);
        // the engine is still perfectly usable afterwards
        engine.set_faults(FaultPlan::none());
        let ok = engine.try_run(&[Query::new(Gemm::new("p", 32, 32, 32))]);
        assert!(ok.outcomes[0].is_ok());
    }

    #[test]
    fn expired_deadlines_shed_instead_of_execute() {
        let mut engine = native_engine();
        let past = Instant::now() - Duration::from_secs(1);
        let wl = Gemm::new("d", 32, 32, 32);
        let window = engine.try_run(&[
            Query::new(wl.clone()).deadline(past),
            Query::new(wl.clone()),
        ]);
        let err = window.outcomes[0].as_ref().unwrap_err();
        assert_eq!(err.kind(), "deadline_exceeded");
        assert!(err.is_shed());
        assert!(window.outcomes[1].as_ref().unwrap().executed);
        assert_eq!(window.metrics.shed_deadline, 1);
        assert_eq!(window.metrics.requests, 1);
        // a generous deadline does not shed
        let far = Instant::now() + Duration::from_secs(3600);
        let ok = engine.try_run(&[Query::new(wl).deadline(far)]);
        assert!(ok.outcomes[0].is_ok());
    }

    #[test]
    fn search_detailed_reports_and_warms() {
        let engine = native_engine();
        let wl = Gemm::new("VI", 512, 256, 256);
        let r = engine
            .search_detailed(0, &wl, Objective::Runtime)
            .unwrap();
        assert!(r.candidates > 0);
        assert!(r.cost().runtime_ms() > 0.0);
        assert!(engine.plan(&wl, Objective::Runtime).unwrap().cache_hit);
        assert!(engine.search_detailed(9, &wl, Objective::Runtime).is_err());
    }
}
