//! Execution runtime — load and execute the AOT artifacts from the L3
//! hot path. Python never runs here: `make artifacts` lowered the L2/L1
//! JAX + Pallas graphs to HLO text once; this module executes them with
//! concrete buffers, either through the built-in native interpreter
//! (default) or a real PJRT client (`--features pjrt`).
//!
//! * [`Manifest`] — the `artifacts/manifest.txt` index (plus
//!   [`Manifest::synthetic`] for artifact-less native runs).
//! * [`Runtime`] — the execution backend with compile-once caching.
//! * [`TiledExecutor`] — the tiled GEMM executor: drives the single-tile
//!   FMA artifact over a FLASH-selected outer schedule, accumulating C
//!   in Rust (the functional mirror of the accelerator's tile
//!   time-multiplexing), plus whole-graph helpers ([`MlpRunner`]).

mod artifacts;
mod client;
mod executor;

pub use artifacts::{ArtifactMeta, Manifest};
pub use client::Runtime;
pub use executor::{MlpRunner, TiledExecutor};

use std::path::PathBuf;

/// Default artifacts directory: `$FLASH_GEMM_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("FLASH_GEMM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
