//! Execution runtime — load and execute the AOT artifacts from the L3
//! hot path. Python never runs here: `make artifacts` lowered the L2/L1
//! JAX + Pallas graphs to HLO text once; this module executes them with
//! concrete buffers, either through the built-in native interpreter
//! (default) or a real PJRT client (`--features pjrt`).
//!
//! * [`Manifest`] — the `artifacts/manifest.txt` index (plus
//!   [`Manifest::synthetic`] for artifact-less native runs).
//! * [`Runtime`] — the execution backend with compile-once caching.
//! * [`PackedGemm`] — the zero-allocation, rayon-parallel packed-panel
//!   execution engine: operands packed once into panels, C in a flat
//!   tile arena, independent output tiles fanned across threads
//!   (bit-identical to the serial per-tile walk). The per-block FMA goes
//!   through a [`KernelKind`] micro-kernel selected from a tile-size/
//!   alignment table ([`kernel_table`]); wide register-blocked kernels
//!   dispatch under `--features simd`, and every kernel is bit-identical
//!   to the scalar path.
//! * [`TiledExecutor`] — the tiled GEMM executor front-end: drives the
//!   tile-kernel contract over a FLASH-selected outer schedule through
//!   the packed engine (native) or per-tile artifact dispatch (PJRT),
//!   plus whole-graph helpers ([`MlpRunner`]).

mod artifacts;
mod client;
mod executor;

pub use artifacts::{ArtifactMeta, Manifest};
pub use client::{kernel_table, selected_kernel, KernelKind, Runtime};
pub use executor::{MlpRunner, PackedGemm, PackedOperands, TiledExecutor};

use std::path::PathBuf;

/// Default artifacts directory: `$FLASH_GEMM_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("FLASH_GEMM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
