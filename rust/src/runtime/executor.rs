//! The tiled GEMM execution engine: L3 drives the L1 tile-kernel
//! contract over the FLASH-selected outer schedule.
//!
//! Two paths implement the same semantics:
//!
//! * [`PackedGemm`] — the zero-allocation, data-parallel engine (native
//!   backend). Operands are packed once per GEMM into panels (A into
//!   row-panels with k-major t×t blocks, B into column-panels with
//!   row-major blocks), C lives in one flat arena of t×t tiles laid out
//!   in the mapping's walk order, and the independent output tiles fan
//!   over rayon with the k-loop kept innermost per tile. The hot loop
//!   performs no heap allocation: per-thread tile scratch is reused
//!   across every kernel call (asserted by `tests/executor_zero_alloc`).
//! * [`TiledExecutor::gemm_serial`] — the per-tile artifact path: pad,
//!   extract t×t tiles, and invoke the `gemm_tile_{t}` artifact through
//!   [`Runtime::run_f32`] for every (i, j, k) grid point. This is the
//!   bit-identity reference for the packed engine and the only path that
//!   exercises a real PJRT kernel under `--features pjrt`.
//!
//! **Determinism.** Output tiles (i, j) are independent; within one tile
//! the k-blocks are reduced in ascending order with each block product
//! formed in scratch before being added to the accumulator — exactly the
//! `acc + A·B` contract of the tile artifact. Every per-element addition
//! therefore happens in the same order as the serial walk, so the
//! parallel engine is bit-identical to [`TiledExecutor::gemm_serial`]
//! for every loop order, thread count, and schedule
//! (`tests/executor_engine.rs`).

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{anyhow, ensure, Result};
use rayon::prelude::*;

use crate::dataflow::{Dim, LoopOrder};
use crate::workloads::Gemm;

use super::client::{self, KernelKind, Runtime};

thread_local! {
    /// Per-thread reusable tile scratch: one t×t block product lives
    /// here between the micro-kernel and the accumulator add. Grown (at
    /// most once per thread per tile size) at plan-creation time, never
    /// in the hot loop.
    static TILE_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's tile scratch, grown to `tt` elements.
fn with_scratch<R>(tt: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    TILE_SCRATCH.with(|s| {
        let mut v = s.borrow_mut();
        if v.len() < tt {
            v.resize(tt, 0.0);
        }
        f(&mut v[..tt])
    })
}

/// Largest tile-scratch size already broadcast to the rayon pool, so
/// repeated plan creation skips the pool-wide barrier.
static WARMED_TT: AtomicUsize = AtomicUsize::new(0);

/// Pre-size the tile scratch on the current thread and (when called from
/// outside the pool, the first time a size this large is seen) on every
/// rayon worker, so the parallel walk starts with warm arenas and the
/// hot loop never allocates. `rayon::broadcast` is a pool-wide
/// synchronization, so it must not run per GEMM: the high-water mark
/// memoizes it per process. Threads spawned after a warm-up (or a plan
/// built from inside the pool) still grow their scratch lazily in
/// `with_scratch` — one bounded allocation per thread per size, never
/// per tile call.
fn warm_scratch(tt: usize) {
    with_scratch(tt, |_| {});
    if rayon::current_thread_index().is_none() && WARMED_TT.fetch_max(tt, Ordering::Relaxed) < tt
    {
        rayon::broadcast(|_| with_scratch(tt, |_| {}));
    }
}

/// Operands packed into panels for one [`PackedGemm`] plan.
///
/// * `a_panels`: `gm × gk` t×t blocks; block (i, kk) starts at
///   `(i·gk + kk)·t²`, stored k-major (block column contiguous), so the
///   k-loop of output-tile row `i` streams one contiguous row-panel.
/// * `b_panels`: `gn × gk` t×t blocks; block (kk, j) starts at
///   `(j·gk + kk)·t²`, stored row-major, so the k-loop of output-tile
///   column `j` streams one contiguous column-panel.
///
/// Padding to tile multiples happens during the pack (zero fill); there
/// is no separate padded copy of either operand.
#[derive(Debug, Clone)]
pub struct PackedOperands {
    a_panels: Vec<f32>,
    b_panels: Vec<f32>,
}

/// An execution plan for one GEMM shape: tile size, grid geometry, and
/// the mapping-ordered walk of output tiles. Pure data — independent of
/// any [`Runtime`] — so one plan is shared across a whole same-shape
/// batch and across threads.
#[derive(Debug, Clone)]
pub struct PackedGemm {
    m: usize,
    n: usize,
    k: usize,
    t: usize,
    gm: usize,
    gn: usize,
    gk: usize,
    /// Output tiles (i, j) in the mapping's inter-cluster loop order
    /// with K removed — K is the innermost, per-tile reduction loop.
    walk: Vec<(u32, u32)>,
    /// Micro-kernel for the per-block FMA, selected at plan time from
    /// the tile-size/alignment table ([`client::selected_kernel`]). All
    /// kernels are bit-identical; selection only affects speed.
    kernel: KernelKind,
}

impl PackedGemm {
    /// Build a plan for `wl` with square tile `tile`, walking output
    /// tiles in the (i, j) sub-order of the mapping's `order`.
    pub fn new(wl: &Gemm, tile: usize, order: LoopOrder) -> Result<Self> {
        ensure!(tile > 0, "tile size must be positive");
        let (m, n, k) = (wl.m as usize, wl.n as usize, wl.k as usize);
        ensure!(m > 0 && n > 0 && k > 0, "degenerate workload {wl}");
        let (gm, gn, gk) = (m.div_ceil(tile), n.div_ceil(tile), k.div_ceil(tile));
        let m_outer = order
            .0
            .iter()
            .find(|&&d| d != Dim::K)
            .copied()
            .expect("loop order has a non-K dim")
            == Dim::M;
        let mut walk = Vec::with_capacity(gm * gn);
        let (outer, inner) = if m_outer { (gm, gn) } else { (gn, gm) };
        for x in 0..outer {
            for y in 0..inner {
                let (i, j) = if m_outer { (x, y) } else { (y, x) };
                walk.push((i as u32, j as u32));
            }
        }
        warm_scratch(tile * tile);
        Ok(PackedGemm {
            m,
            n,
            k,
            t: tile,
            gm,
            gn,
            gk,
            walk,
            kernel: client::selected_kernel(tile),
        })
    }

    /// Square tile size t.
    pub fn tile(&self) -> usize {
        self.t
    }

    /// The micro-kernel this plan dispatches per k-block.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Override the micro-kernel (equivalence tests and benches compare
    /// kernels through the full engine). Errors if `kernel` does not
    /// support this plan's tile size.
    pub fn with_kernel(mut self, kernel: KernelKind) -> Result<Self> {
        ensure!(
            kernel.supports(self.t),
            "{} kernel does not support tile size {}",
            kernel.name(),
            self.t
        );
        self.kernel = kernel;
        Ok(self)
    }

    /// Tile-grid geometry (gm, gn, gk).
    pub fn grid(&self) -> (usize, usize, usize) {
        (self.gm, self.gn, self.gk)
    }

    /// Tile-kernel invocations one execution performs.
    pub fn tile_calls(&self) -> u64 {
        (self.gm * self.gn * self.gk) as u64
    }

    /// Length of the flat C-tile arena ([`PackedGemm::execute_into`]).
    pub fn c_tiles_len(&self) -> usize {
        self.gm * self.gn * self.t * self.t
    }

    /// Pack operands into panels (the only allocation site of a GEMM
    /// besides the result buffers).
    pub fn pack(&self, a: &[f32], b: &[f32]) -> Result<PackedOperands> {
        ensure!(a.len() == self.m * self.k, "A len {} != {}", a.len(), self.m * self.k);
        ensure!(b.len() == self.k * self.n, "B len {} != {}", b.len(), self.k * self.n);
        Ok(PackedOperands {
            a_panels: self.pack_a_panels(a),
            b_panels: self.pack_b_panels(b),
        })
    }

    /// Pack only the weights: a fused chain fills the A-panel arena
    /// straight from its producer's output tiles
    /// ([`PackedGemm::execute_fused_into_a_panels`]), so A is left as
    /// the zeroed arena the pack would otherwise pad into.
    pub fn pack_b(&self, b: &[f32]) -> Result<PackedOperands> {
        ensure!(b.len() == self.k * self.n, "B len {} != {}", b.len(), self.k * self.n);
        Ok(PackedOperands {
            a_panels: vec![0f32; self.gm * self.gk * self.t * self.t],
            b_panels: self.pack_b_panels(b),
        })
    }

    /// A row-panels, k-major blocks (zero padded to tile multiples).
    fn pack_a_panels(&self, a: &[f32]) -> Vec<f32> {
        let (t, tt) = (self.t, self.t * self.t);
        let mut a_panels = vec![0f32; self.gm * self.gk * tt];
        for bi in 0..self.gm {
            let rows = t.min(self.m - bi * t);
            for bk in 0..self.gk {
                let cols = t.min(self.k - bk * t);
                let base = (bi * self.gk + bk) * tt;
                for r in 0..rows {
                    let src = &a[(bi * t + r) * self.k + bk * t..][..cols];
                    for (kl, &v) in src.iter().enumerate() {
                        a_panels[base + kl * t + r] = v;
                    }
                }
            }
        }
        a_panels
    }

    /// B column-panels, row-major blocks (zero padded to tile multiples).
    fn pack_b_panels(&self, b: &[f32]) -> Vec<f32> {
        let (t, tt) = (self.t, self.t * self.t);
        let mut b_panels = vec![0f32; self.gn * self.gk * tt];
        for bj in 0..self.gn {
            let cols = t.min(self.n - bj * t);
            for bk in 0..self.gk {
                let rows = t.min(self.k - bk * t);
                let base = (bj * self.gk + bk) * tt;
                for r in 0..rows {
                    let src = &b[(bk * t + r) * self.n + bj * t..][..cols];
                    b_panels[base + r * t..base + r * t + cols].copy_from_slice(src);
                }
            }
        }
        b_panels
    }

    /// Accumulate output tile (i, j): reduce its gk k-blocks in
    /// ascending order. Each block product is formed in `scratch` and
    /// then added to the tile — the `acc + A·B` artifact contract —
    /// which is what makes the result bit-identical to the serial
    /// per-tile path. Zero heap allocation.
    fn accumulate_tile(
        &self,
        ops: &PackedOperands,
        ctile: &mut [f32],
        scratch: &mut [f32],
        i: usize,
        j: usize,
    ) {
        let tt = self.t * self.t;
        let a_panel = &ops.a_panels[i * self.gk * tt..(i + 1) * self.gk * tt];
        let b_panel = &ops.b_panels[j * self.gk * tt..(j + 1) * self.gk * tt];
        for (a_blk, b_blk) in a_panel.chunks_exact(tt).zip(b_panel.chunks_exact(tt)) {
            scratch.fill(0.0);
            self.kernel.apply(scratch, a_blk, b_blk, self.t);
            for (cv, &sv) in ctile.iter_mut().zip(scratch.iter()) {
                *cv += sv;
            }
        }
    }

    /// The parallel hot loop: fan the walk-ordered C-tile arena over
    /// rayon (each chunk of the walk stays in mapping order within its
    /// thread). `c_tiles` must be `c_tiles_len()` long and holds the
    /// accumulator (zero it for a plain product). No heap allocation.
    pub fn execute_into(&self, ops: &PackedOperands, c_tiles: &mut [f32]) {
        let tt = self.t * self.t;
        assert_eq!(c_tiles.len(), self.c_tiles_len(), "C-tile arena length");
        c_tiles
            .par_chunks_mut(tt)
            .zip_eq(self.walk.par_iter())
            .for_each(|(ctile, &(i, j))| {
                with_scratch(tt, |scratch| {
                    self.accumulate_tile(ops, ctile, scratch, i as usize, j as usize)
                })
            });
    }

    /// Single-threaded hot loop with identical semantics (and identical
    /// bits) to [`PackedGemm::execute_into`]. No heap allocation —
    /// `tests/executor_zero_alloc.rs` counts.
    pub fn execute_serial_into(&self, ops: &PackedOperands, c_tiles: &mut [f32]) {
        let tt = self.t * self.t;
        assert_eq!(c_tiles.len(), self.c_tiles_len(), "C-tile arena length");
        with_scratch(tt, |scratch| {
            for (ctile, &(i, j)) in c_tiles.chunks_exact_mut(tt).zip(&self.walk) {
                self.accumulate_tile(ops, ctile, scratch, i as usize, j as usize);
            }
        });
    }

    /// [`PackedGemm::execute_into`] with an elementwise epilogue applied
    /// in-tile: after a tile's k-reduction finishes, `epi(tile, i, j,
    /// rows, cols)` runs on it before the next tile starts (`rows`/`cols`
    /// bound the valid region — the zero-padded lanes outside it must
    /// stay untouched so a fused consumer reads the padding it expects).
    /// The tile is row-major with stride `tile()`. Bit-identical to
    /// executing first and applying the same elementwise function to the
    /// unpacked matrix afterwards: each output element sees exactly one
    /// epilogue application on the fully reduced value.
    pub fn execute_epilogued_into<F>(&self, ops: &PackedOperands, c_tiles: &mut [f32], epi: &F)
    where
        F: Fn(&mut [f32], usize, usize, usize, usize) + Sync,
    {
        let (t, tt) = (self.t, self.t * self.t);
        assert_eq!(c_tiles.len(), self.c_tiles_len(), "C-tile arena length");
        c_tiles
            .par_chunks_mut(tt)
            .zip_eq(self.walk.par_iter())
            .for_each(|(ctile, &(i, j))| {
                let (i, j) = (i as usize, j as usize);
                with_scratch(tt, |scratch| self.accumulate_tile(ops, ctile, scratch, i, j));
                epi(ctile, i, j, t.min(self.m - i * t), t.min(self.n - j * t));
            });
    }

    /// The fused chain hot path: execute this GEMM, apply the epilogue
    /// in-tile, and write each finished tile **transposed** straight into
    /// `consumer`'s A-panel arena (`next.a_panels`, from
    /// [`PackedGemm::pack_b`]) — the intermediate matrix is never
    /// unpacked or repacked. Legal when the consumer reads this output
    /// directly as its A operand with the same tile size: its block
    /// (i, kk) is exactly our output tile (i, j=kk) with rows and
    /// columns swapped (A panels are k-major). Zero-padded lanes carry
    /// straight through, which is why `epi` must not touch them.
    pub fn execute_fused_into_a_panels<F>(
        &self,
        ops: &PackedOperands,
        consumer: &PackedGemm,
        next: &mut PackedOperands,
        epi: &F,
    ) -> Result<()>
    where
        F: Fn(&mut [f32], usize, usize, usize, usize) + Sync,
    {
        let (t, tt) = (self.t, self.t * self.t);
        ensure!(
            consumer.t == t && consumer.m == self.m && consumer.k == self.n,
            "fused handoff shape mismatch: {}x{} t{} feeding m{} k{} t{}",
            self.m,
            self.n,
            t,
            consumer.m,
            consumer.k,
            consumer.t
        );
        ensure!(
            next.a_panels.len() == consumer.gm * consumer.gk * tt,
            "consumer A-panel arena length"
        );
        // one warm 2·t² grow per thread per size, outside the hot loop
        warm_scratch(2 * tt);
        next.a_panels
            .par_chunks_mut(tt)
            .enumerate()
            .for_each(|(blk, panel)| {
                // consumer block (i, kk) == our output tile (i, j=kk);
                // output tiles are order-independent, so walking the
                // consumer's panel order preserves bit-identity
                let (i, j) = (blk / consumer.gk, blk % consumer.gk);
                with_scratch(2 * tt, |s| {
                    let (acc, scratch) = s.split_at_mut(tt);
                    acc.fill(0.0);
                    self.accumulate_tile(ops, acc, scratch, i, j);
                    epi(acc, i, j, t.min(self.m - i * t), t.min(self.n - j * t));
                    for r in 0..t {
                        for c in 0..t {
                            panel[c * t + r] = acc[r * t + c];
                        }
                    }
                });
            });
        Ok(())
    }

    /// Scatter the walk-ordered C-tile arena into the unpadded row-major
    /// `m×n` result.
    pub fn unpack_into(&self, c_tiles: &[f32], c: &mut [f32]) {
        let (t, tt) = (self.t, self.t * self.t);
        assert_eq!(c.len(), self.m * self.n, "C length");
        for (tile, &(i, j)) in c_tiles.chunks_exact(tt).zip(&self.walk) {
            let (i, j) = (i as usize, j as usize);
            let rows = t.min(self.m - i * t);
            let cols = t.min(self.n - j * t);
            for (r, trow) in tile.chunks_exact(t).take(rows).enumerate() {
                c[(i * t + r) * self.n + j * t..][..cols].copy_from_slice(&trow[..cols]);
            }
        }
    }

    /// Parallel execution over pre-packed operands.
    pub fn execute(&self, ops: &PackedOperands) -> Vec<f32> {
        let mut c_tiles = vec![0f32; self.c_tiles_len()];
        self.execute_into(ops, &mut c_tiles);
        let mut c = vec![0f32; self.m * self.n];
        self.unpack_into(&c_tiles, &mut c);
        c
    }

    /// Pack + parallel execute + unpack: `A · B` for row-major f32.
    pub fn run(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let ops = self.pack(a, b)?;
        Ok(self.execute(&ops))
    }

    /// Pack + serial execute + unpack (bit-identical to [`PackedGemm::run`]).
    pub fn run_serial(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let ops = self.pack(a, b)?;
        let mut c_tiles = vec![0f32; self.c_tiles_len()];
        self.execute_serial_into(&ops, &mut c_tiles);
        let mut c = vec![0f32; self.m * self.n];
        self.unpack_into(&c_tiles, &mut c);
        Ok(c)
    }
}

/// Pad a row-major `rows×cols` matrix to `prows×pcols` (serial artifact
/// path only — the packed engine pads during the pack).
fn pad(m: &[f32], rows: usize, cols: usize, prows: usize, pcols: usize) -> Vec<f32> {
    let mut out = vec![0f32; prows * pcols];
    for r in 0..rows {
        out[r * pcols..r * pcols + cols].copy_from_slice(&m[r * cols..(r + 1) * cols]);
    }
    out
}

/// Extract the t×t tile at (tile row `i`, tile col `j`) of a padded
/// matrix with `pcols` columns (serial artifact path only).
fn tile(m: &[f32], pcols: usize, i: usize, j: usize, t: usize, out: &mut Vec<f32>) {
    out.clear();
    for r in 0..t {
        let base = (i * t + r) * pcols + j * t;
        out.extend_from_slice(&m[base..base + t]);
    }
}

/// Tiled GEMM over the tile artifact: the packed parallel engine on the
/// native backend, the per-tile artifact path on PJRT.
pub struct TiledExecutor<'r> {
    runtime: &'r mut Runtime,
    /// Square tile size t (must have a `gemm_tile_{t}` artifact).
    pub tile: usize,
    /// Tile-grid traversal order (from the FLASH mapping).
    pub order: LoopOrder,
    /// Kernel invocations performed (packed-engine FMAs included).
    pub tile_calls: u64,
}

impl<'r> TiledExecutor<'r> {
    /// Pick the largest available tile that does not exceed the smallest
    /// workload dimension (falling back to the smallest artifact when
    /// even that is too big). A tile larger than `min(M, N, K)` only
    /// inflates padding and wasted FMAs — it can never reduce the tile
    /// count below 1 in the short dimension.
    pub fn auto_tile(runtime: &Runtime, wl: &Gemm) -> u64 {
        let dims_min = wl.m.min(wl.n).min(wl.k);
        let sizes = runtime.manifest().tile_sizes();
        sizes
            .iter()
            .rev()
            .find(|&&t| t <= dims_min)
            .copied()
            .or_else(|| sizes.first().copied())
            .unwrap_or(16)
    }

    pub fn new(runtime: &'r mut Runtime, tile: usize, order: LoopOrder) -> Result<Self> {
        let name = format!("gemm_tile_{tile}");
        if runtime.manifest().get(&name).is_none() {
            return Err(anyhow!(
                "no tile artifact {name}; available tiles: {:?}",
                runtime.manifest().tile_sizes()
            ));
        }
        runtime.warm(&name)?;
        Ok(TiledExecutor {
            runtime,
            tile,
            order,
            tile_calls: 0,
        })
    }

    /// Compute `A · B` (row-major f32) through the tile-kernel contract:
    /// the packed parallel engine on the native backend, the per-tile
    /// artifact dispatch otherwise. Both produce bit-identical results.
    pub fn gemm(&mut self, wl: &Gemm, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        if !self.runtime.is_native() {
            return self.gemm_serial(wl, a, b);
        }
        let plan = PackedGemm::new(wl, self.tile, self.order)?;
        let c = plan.run(a, b)?;
        self.tile_calls += plan.tile_calls();
        self.runtime.note_executions(plan.tile_calls());
        Ok(c)
    }

    /// The serial per-tile artifact path: pad the operands, walk the
    /// (m, n, k) tile grid in the mapping's inter-cluster loop order,
    /// and invoke the `gemm_tile_{t}` artifact per grid point. This is
    /// the bit-identity reference for [`TiledExecutor::gemm`] and the
    /// execution path for a real PJRT kernel.
    pub fn gemm_serial(&mut self, wl: &Gemm, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (m, n, k) = (wl.m as usize, wl.n as usize, wl.k as usize);
        ensure!(a.len() == m * k, "A len {} != {}", a.len(), m * k);
        ensure!(b.len() == k * n, "B len {} != {}", b.len(), k * n);
        let t = self.tile;
        let name = format!("gemm_tile_{t}");
        let (pm, pn, pk) = (m.div_ceil(t) * t, n.div_ceil(t) * t, k.div_ceil(t) * t);
        let pa = pad(a, m, k, pm, pk);
        let pb = pad(b, k, n, pk, pn);
        let (gm, gn, gk) = (pm / t, pn / t, pk / t);

        // C accumulators, one t×t buffer per (i, j) tile.
        let mut c_tiles: Vec<Vec<f32>> = vec![vec![0f32; t * t]; gm * gn];
        let mut ta = Vec::with_capacity(t * t);
        let mut tb = Vec::with_capacity(t * t);

        // Walk the tile grid in the mapping's inter-cluster loop order.
        let counts = |d: Dim| match d {
            Dim::M => gm,
            Dim::N => gn,
            Dim::K => gk,
        };
        let dims = self.order.0;
        let shape = [t as u64, t as u64];
        for x0 in 0..counts(dims[0]) {
            for x1 in 0..counts(dims[1]) {
                for x2 in 0..counts(dims[2]) {
                    let idx = |d: Dim| {
                        let pos = self.order.position(d);
                        [x0, x1, x2][pos]
                    };
                    let (i, j, kk) = (idx(Dim::M), idx(Dim::N), idx(Dim::K));
                    tile(&pa, pk, i, kk, t, &mut ta);
                    tile(&pb, pn, kk, j, t, &mut tb);
                    let acc = &c_tiles[i * gn + j];
                    let out = self
                        .runtime
                        .run_f32(&name, &[(acc, shape), (&ta, shape), (&tb, shape)])?;
                    c_tiles[i * gn + j] = out;
                    self.tile_calls += 1;
                }
            }
        }

        // Reassemble the unpadded C.
        let mut c = vec![0f32; m * n];
        for i in 0..gm {
            for j in 0..gn {
                let src = &c_tiles[i * gn + j];
                for r in 0..t {
                    let row = i * t + r;
                    if row >= m {
                        break;
                    }
                    let col0 = j * t;
                    let w = t.min(n.saturating_sub(col0));
                    if w == 0 {
                        continue;
                    }
                    c[row * n + col0..row * n + col0 + w].copy_from_slice(&src[r * t..r * t + w]);
                }
            }
        }
        Ok(c)
    }
}

/// Run the Fig 10 MLP artifact (batch 128 MNIST classifier).
pub struct MlpRunner;

impl MlpRunner {
    /// Dims of the paper's MLP (must match `python/compile/model.py`).
    pub const DIMS: [u64; 5] = [784, 512, 256, 128, 10];
    pub const BATCH: u64 = 128;

    /// Execute one inference batch; returns the (BATCH × 10) logits.
    pub fn forward(runtime: &mut Runtime, x: &[f32], weights: &[Vec<f32>]) -> Result<Vec<f32>> {
        anyhow::ensure!(weights.len() == 4, "want 4 weight matrices");
        let d = Self::DIMS;
        let mut args: Vec<(&[f32], [u64; 2])> = vec![(x, [Self::BATCH, d[0]])];
        for (i, w) in weights.iter().enumerate() {
            anyhow::ensure!(
                w.len() as u64 == d[i] * d[i + 1],
                "weight {i} len {} != {}",
                w.len(),
                d[i] * d[i + 1]
            );
            args.push((w, [d[i], d[i + 1]]));
        }
        runtime.run_f32("mlp", &args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn pad_and_tile_roundtrip() {
        // 2×3 matrix padded to 4×4
        let m = [1., 2., 3., 4., 5., 6.];
        let p = pad(&m, 2, 3, 4, 4);
        assert_eq!(p[0..3], [1., 2., 3.]);
        assert_eq!(p[3], 0.0);
        assert_eq!(p[4..7], [4., 5., 6.]);
        assert_eq!(p[8..], [0.0; 8][..]);
        let mut t2 = Vec::new();
        tile(&p, 4, 0, 0, 2, &mut t2);
        assert_eq!(t2, vec![1., 2., 4., 5.]);
        tile(&p, 4, 0, 1, 2, &mut t2);
        assert_eq!(t2, vec![3., 0., 6., 0.]);
    }

    #[test]
    fn pack_layouts_and_padding() {
        // A = 2×3 (m=2, k=3), tile 2 → gm=1, gk=2; k-major blocks.
        let wl = Gemm::new("p", 2, 2, 3);
        let plan = PackedGemm::new(&wl, 2, LoopOrder::MNK).unwrap();
        assert_eq!(plan.grid(), (1, 1, 2));
        let a = [1., 2., 3., 4., 5., 6.]; // rows [1 2 3], [4 5 6]
        let b = [1., 0., 0., 1., 1., 1.]; // 3×2
        let ops = plan.pack(&a, &b).unwrap();
        // block (0,0) k-major: col k0 = [1,4], col k1 = [2,5]
        assert_eq!(ops.a_panels[0..4], [1., 4., 2., 5.]);
        // block (0,1): col k2 = [3,6], padded col = zeros
        assert_eq!(ops.a_panels[4..8], [3., 6., 0., 0.]);
        // B block (k0,j0) row-major rows [1 0], [0 1]; block (k1,j0) row
        // [1 1] then zero padding
        assert_eq!(ops.b_panels[0..4], [1., 0., 0., 1.]);
        assert_eq!(ops.b_panels[4..8], [1., 1., 0., 0.]);
    }

    #[test]
    fn walk_follows_mapping_mn_suborder() {
        let wl = Gemm::new("w", 4, 6, 2);
        // MNK → i-outer, j-inner
        let p = PackedGemm::new(&wl, 2, LoopOrder::MNK).unwrap();
        assert_eq!(p.walk[..4], [(0, 0), (0, 1), (0, 2), (1, 0)]);
        // NKM → j-outer, i-inner
        let p = PackedGemm::new(&wl, 2, LoopOrder::NKM).unwrap();
        assert_eq!(p.walk[..4], [(0, 0), (1, 0), (0, 1), (1, 1)]);
        // KMN keeps M before N once K is stripped
        let p = PackedGemm::new(&wl, 2, LoopOrder::KMN).unwrap();
        assert_eq!(p.walk[..4], [(0, 0), (0, 1), (0, 2), (1, 0)]);
    }

    #[test]
    fn packed_engine_small_known_product() {
        // 2×2: C = A·B with a ragged k
        let wl = Gemm::new("s", 2, 2, 3);
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [1., 0., 0., 1., 1., 1.];
        let want = vec![1. + 3., 2. + 3., 4. + 6., 5. + 6.];
        for t in [1usize, 2, 4] {
            let plan = PackedGemm::new(&wl, t, LoopOrder::MNK).unwrap();
            assert_eq!(plan.run(&a, &b).unwrap(), want, "t={t}");
            assert_eq!(plan.run_serial(&a, &b).unwrap(), want, "t={t} serial");
        }
    }

    #[test]
    fn plan_kernel_override_is_bit_identical_and_checked() {
        let wl = Gemm::new("k", 20, 20, 20);
        let a: Vec<f32> = (0..400).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..400).map(|i| (i as f32).cos()).collect();
        let base = PackedGemm::new(&wl, 8, LoopOrder::MNK).unwrap();
        let want = base
            .clone()
            .with_kernel(KernelKind::Scalar)
            .unwrap()
            .run(&a, &b)
            .unwrap();
        for kind in [KernelKind::Blocked4x4, KernelKind::Blocked4x8] {
            let plan = base.clone().with_kernel(kind).unwrap();
            assert_eq!(plan.kernel(), kind);
            assert_eq!(plan.run(&a, &b).unwrap(), want, "{}", kind.name());
        }
        // tile 6 is not 4-aligned: blocked kernels must be rejected
        let odd = PackedGemm::new(&wl, 6, LoopOrder::MNK).unwrap();
        assert!(odd.with_kernel(KernelKind::Blocked4x4).is_err());
    }

    #[test]
    fn fused_handoff_is_bit_identical_to_unfused_repack() {
        // chain: C1 = epi(A·B1), C2 = C1·B2 — ragged in every dim
        let wl1 = Gemm::new("s1", 5, 7, 3);
        let wl2 = Gemm::new("s2", 5, 4, 7);
        let t = 2usize;
        let a: Vec<f32> = (0..15).map(|i| (i as f32).sin()).collect();
        let b1: Vec<f32> = (0..21).map(|i| (i as f32).cos()).collect();
        let b2: Vec<f32> = (0..28).map(|i| (i as f32 * 0.3).sin()).collect();
        let p1 = PackedGemm::new(&wl1, t, LoopOrder::MNK).unwrap();
        let p2 = PackedGemm::new(&wl2, t, LoopOrder::NKM).unwrap();
        // scale + per-column bias + relu, valid region only
        let epi = |tile: &mut [f32], _i: usize, j: usize, rows: usize, cols: usize| {
            for r in 0..rows {
                for c in 0..cols {
                    let v = &mut tile[r * t + c];
                    *v = (*v * 1.5 + (j * t + c) as f32).max(0.0);
                }
            }
        };
        // unfused reference: run, epilogue the matrix, repack, run
        let mut c1 = p1.run(&a, &b1).unwrap();
        for r in 0..5 {
            for c in 0..7 {
                let v = &mut c1[r * 7 + c];
                *v = (*v * 1.5 + c as f32).max(0.0);
            }
        }
        let want = p2.run(&c1, &b2).unwrap();
        // in-tile epilogue path matches the matrix epilogue bit-for-bit
        let ops1 = p1.pack(&a, &b1).unwrap();
        let mut c_tiles = vec![0.0; p1.c_tiles_len()];
        p1.execute_epilogued_into(&ops1, &mut c_tiles, &epi);
        let mut got = vec![0.0; 5 * 7];
        p1.unpack_into(&c_tiles, &mut got);
        assert_eq!(got, c1);
        // fused handoff: epilogued tiles land in p2's A panels directly
        let mut ops2 = p2.pack_b(&b2).unwrap();
        p1.execute_fused_into_a_panels(&ops1, &p2, &mut ops2, &epi)
            .unwrap();
        assert_eq!(p2.execute(&ops2), want);
        // a shape-incompatible consumer is rejected, not silently fused
        assert!(p1.execute_fused_into_a_panels(&ops1, &p1, &mut ops2, &epi).is_err());
    }

    #[test]
    fn plan_rejects_bad_inputs() {
        let wl = Gemm::new("r", 4, 4, 4);
        assert!(PackedGemm::new(&wl, 0, LoopOrder::MNK).is_err());
        let plan = PackedGemm::new(&wl, 2, LoopOrder::MNK).unwrap();
        assert!(plan.pack(&[0.0; 3], &[0.0; 16]).is_err());
        assert!(plan.pack(&[0.0; 16], &[0.0; 3]).is_err());
    }

    #[test]
    fn auto_tile_never_exceeds_min_dim() {
        let rt = Runtime::native(Manifest::synthetic(&[4, 8, 16]));
        // dims_min = 5: the old next_power_of_two logic picked 8
        assert_eq!(TiledExecutor::auto_tile(&rt, &Gemm::new("a", 5, 7, 6)), 4);
        assert_eq!(TiledExecutor::auto_tile(&rt, &Gemm::new("b", 100, 100, 100)), 16);
        assert_eq!(TiledExecutor::auto_tile(&rt, &Gemm::new("c", 8, 9, 10)), 8);
        // nothing fits → smallest artifact
        assert_eq!(TiledExecutor::auto_tile(&rt, &Gemm::new("d", 2, 2, 2)), 4);
    }

    #[test]
    fn executor_dispatch_counts_tile_calls() {
        let mut rt = Runtime::native(Manifest::synthetic(&[2]));
        let wl = Gemm::new("x", 4, 4, 4);
        let a = [0.5f32; 16];
        let b = [0.25f32; 16];
        let mut exec = TiledExecutor::new(&mut rt, 2, LoopOrder::MNK).unwrap();
        let c = exec.gemm(&wl, &a, &b).unwrap();
        assert_eq!(exec.tile_calls, 8); // 2×2×2 grid
        assert_eq!(c, vec![0.5; 16]);
    }

    #[test]
    fn executions_accounting_matches_tile_calls() {
        let mut rt = Runtime::native(Manifest::synthetic(&[2]));
        let wl = Gemm::new("x", 4, 4, 4);
        let a = [1.0f32; 16];
        let b = [1.0f32; 16];
        {
            let mut exec = TiledExecutor::new(&mut rt, 2, LoopOrder::MNK).unwrap();
            exec.gemm(&wl, &a, &b).unwrap();
        }
        assert_eq!(rt.executions, 8);
    }
}
