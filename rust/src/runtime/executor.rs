//! The tiled GEMM executor: L3 drives the L1 kernel artifact over the
//! FLASH-selected outer schedule.
//!
//! `gemm_tile_{t}` computes `acc + A_tile · B_tile` for t×t f32 tiles
//! (the Pallas kernel's FMA unit). The executor pads the operands to
//! tile multiples, walks the (m, n, k) tile grid in the mapping's
//! inter-cluster loop order, and accumulates C — the functional mirror
//! of the accelerator time-multiplexing its PE array over outer tiles.

use anyhow::{anyhow, Result};

use crate::dataflow::{Dim, LoopOrder};
use crate::workloads::Gemm;

use super::client::Runtime;

/// Pad a row-major `rows×cols` matrix to `prows×pcols`.
fn pad(m: &[f32], rows: usize, cols: usize, prows: usize, pcols: usize) -> Vec<f32> {
    let mut out = vec![0f32; prows * pcols];
    for r in 0..rows {
        out[r * pcols..r * pcols + cols].copy_from_slice(&m[r * cols..(r + 1) * cols]);
    }
    out
}

/// Extract the t×t tile at (tile row `i`, tile col `j`) of a padded
/// matrix with `pcols` columns.
fn tile(m: &[f32], pcols: usize, i: usize, j: usize, t: usize, out: &mut Vec<f32>) {
    out.clear();
    for r in 0..t {
        let base = (i * t + r) * pcols + j * t;
        out.extend_from_slice(&m[base..base + t]);
    }
}

/// Tiled GEMM over the PJRT tile artifact.
pub struct TiledExecutor<'r> {
    runtime: &'r mut Runtime,
    /// Square tile size t (must have a `gemm_tile_{t}` artifact).
    pub tile: usize,
    /// Tile-grid traversal order (from the FLASH mapping).
    pub order: LoopOrder,
    /// Kernel invocations performed.
    pub tile_calls: u64,
}

impl<'r> TiledExecutor<'r> {
    /// Pick the largest available tile not exceeding the workload dims.
    pub fn auto_tile(runtime: &Runtime, wl: &Gemm) -> u64 {
        let dims_min = wl.m.min(wl.n).min(wl.k);
        let sizes = runtime.manifest().tile_sizes();
        sizes
            .iter()
            .rev()
            .find(|&&t| t <= dims_min.next_power_of_two())
            .copied()
            .or_else(|| sizes.first().copied())
            .unwrap_or(16)
    }

    pub fn new(runtime: &'r mut Runtime, tile: usize, order: LoopOrder) -> Result<Self> {
        let name = format!("gemm_tile_{tile}");
        if runtime.manifest().get(&name).is_none() {
            return Err(anyhow!(
                "no tile artifact {name}; available tiles: {:?}",
                runtime.manifest().tile_sizes()
            ));
        }
        runtime.warm(&name)?;
        Ok(TiledExecutor {
            runtime,
            tile,
            order,
            tile_calls: 0,
        })
    }

    /// Compute `A · B` (row-major f32) through the tile artifact.
    pub fn gemm(&mut self, wl: &Gemm, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (m, n, k) = (wl.m as usize, wl.n as usize, wl.k as usize);
        anyhow::ensure!(a.len() == m * k, "A len {} != {}", a.len(), m * k);
        anyhow::ensure!(b.len() == k * n, "B len {} != {}", b.len(), k * n);
        let t = self.tile;
        let name = format!("gemm_tile_{t}");
        let (pm, pn, pk) = (m.div_ceil(t) * t, n.div_ceil(t) * t, k.div_ceil(t) * t);
        let pa = pad(a, m, k, pm, pk);
        let pb = pad(b, k, n, pk, pn);
        let (gm, gn, gk) = (pm / t, pn / t, pk / t);

        // C accumulators, one t×t buffer per (i, j) tile.
        let mut c_tiles: Vec<Vec<f32>> = vec![vec![0f32; t * t]; gm * gn];
        let mut ta = Vec::with_capacity(t * t);
        let mut tb = Vec::with_capacity(t * t);

        // Walk the tile grid in the mapping's inter-cluster loop order.
        let counts = |d: Dim| match d {
            Dim::M => gm,
            Dim::N => gn,
            Dim::K => gk,
        };
        let dims = self.order.0;
        let shape = [t as u64, t as u64];
        for x0 in 0..counts(dims[0]) {
            for x1 in 0..counts(dims[1]) {
                for x2 in 0..counts(dims[2]) {
                    let idx = |d: Dim| {
                        let pos = self.order.position(d);
                        [x0, x1, x2][pos]
                    };
                    let (i, j, kk) = (idx(Dim::M), idx(Dim::N), idx(Dim::K));
                    tile(&pa, pk, i, kk, t, &mut ta);
                    tile(&pb, pn, kk, j, t, &mut tb);
                    let acc = &c_tiles[i * gn + j];
                    let out = self.runtime.run_f32(
                        &name,
                        &[(acc, shape), (&ta, shape), (&tb, shape)],
                    )?;
                    c_tiles[i * gn + j] = out;
                    self.tile_calls += 1;
                }
            }
        }

        // Reassemble the unpadded C.
        let mut c = vec![0f32; m * n];
        for i in 0..gm {
            for j in 0..gn {
                let src = &c_tiles[i * gn + j];
                for r in 0..t {
                    let row = i * t + r;
                    if row >= m {
                        break;
                    }
                    let col0 = j * t;
                    let w = t.min(n.saturating_sub(col0));
                    if w == 0 {
                        continue;
                    }
                    c[row * n + col0..row * n + col0 + w].copy_from_slice(&src[r * t..r * t + w]);
                }
            }
        }
        Ok(c)
    }
}

/// Run the Fig 10 MLP artifact (batch 128 MNIST classifier).
pub struct MlpRunner;

impl MlpRunner {
    /// Dims of the paper's MLP (must match `python/compile/model.py`).
    pub const DIMS: [u64; 5] = [784, 512, 256, 128, 10];
    pub const BATCH: u64 = 128;

    /// Execute one inference batch; returns the (BATCH × 10) logits.
    pub fn forward(runtime: &mut Runtime, x: &[f32], weights: &[Vec<f32>]) -> Result<Vec<f32>> {
        anyhow::ensure!(weights.len() == 4, "want 4 weight matrices");
        let d = Self::DIMS;
        let mut args: Vec<(&[f32], [u64; 2])> = vec![(x, [Self::BATCH, d[0]])];
        for (i, w) in weights.iter().enumerate() {
            anyhow::ensure!(
                w.len() as u64 == d[i] * d[i + 1],
                "weight {i} len {} != {}",
                w.len(),
                d[i] * d[i + 1]
            );
            args.push((w, [d[i], d[i + 1]]));
        }
        runtime.run_f32("mlp", &args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_tile_roundtrip() {
        // 2×3 matrix padded to 4×4
        let m = [1., 2., 3., 4., 5., 6.];
        let p = pad(&m, 2, 3, 4, 4);
        assert_eq!(p[0..3], [1., 2., 3.]);
        assert_eq!(p[3], 0.0);
        assert_eq!(p[4..7], [4., 5., 6.]);
        assert_eq!(p[8..], [0.0; 8][..]);
        let mut t2 = Vec::new();
        tile(&p, 4, 0, 0, 2, &mut t2);
        assert_eq!(t2, vec![1., 2., 4., 5.]);
        tile(&p, 4, 0, 1, 2, &mut t2);
        assert_eq!(t2, vec![3., 0., 6., 0.]);
    }
}
