//! The artifact manifest: what the AOT pipeline produced.
//!
//! `python/compile/aot.py` writes `manifest.txt` (line-based; the build
//! image is offline so the Rust side avoids a JSON dependency):
//! `name path shape shape ...`, shapes like `128x784`, all f32.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT artifact: a lowered HLO-text computation and its argument
/// shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    /// Argument shapes, row-major, all f32.
    pub arg_shapes: Vec<Vec<u64>>,
}

impl ArtifactMeta {
    /// Total argument elements (sanity/cost accounting).
    pub fn arg_elems(&self) -> u64 {
        self.arg_shapes
            .iter()
            .map(|s| s.iter().product::<u64>())
            .sum()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let (Some(name), Some(path)) = (parts.next(), parts.next()) else {
                bail!("manifest line {}: want `name path shapes...`", lineno + 1);
            };
            let mut arg_shapes = Vec::new();
            for shape in parts {
                let dims: Result<Vec<u64>, _> =
                    shape.split('x').map(|d| d.parse::<u64>()).collect();
                arg_shapes.push(dims.with_context(|| {
                    format!("manifest line {}: bad shape {shape:?}", lineno + 1)
                })?);
            }
            artifacts.push(ArtifactMeta {
                name: name.to_string(),
                path: dir.join(path),
                arg_shapes,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// An in-memory manifest of square tile kernels (`gemm_tile_{t}`),
    /// no files behind it — for the native backend when no artifacts
    /// directory exists (tests, demos without `make artifacts`).
    pub fn synthetic(tiles: &[u64]) -> Self {
        let dir = PathBuf::from("<synthetic>");
        let artifacts = tiles
            .iter()
            .map(|&t| ArtifactMeta {
                name: format!("gemm_tile_{t}"),
                path: dir.join(format!("gemm_tile_{t}.hlo.txt")),
                arg_shapes: vec![vec![t, t]; 3],
            })
            .collect();
        Manifest { dir, artifacts }
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Square tile sizes for which a `gemm_tile_{t}` artifact exists,
    /// ascending.
    pub fn tile_sizes(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self
            .artifacts
            .iter()
            .filter_map(|a| a.name.strip_prefix("gemm_tile_")?.parse().ok())
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
gemm_tile_16 gemm_tile_16.hlo.txt 16x16 16x16 16x16
mlp mlp.hlo.txt 128x784 784x512 512x256 256x128 128x10
";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let t = m.get("gemm_tile_16").unwrap();
        assert_eq!(t.arg_shapes, vec![vec![16, 16]; 3]);
        assert_eq!(t.arg_elems(), 3 * 256);
        assert_eq!(m.tile_sizes(), vec![16]);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Manifest::parse(Path::new("."), "name-only\n").is_err());
        assert!(Manifest::parse(Path::new("."), "a b 12xfoo\n").is_err());
        assert!(Manifest::parse(Path::new("."), "# empty\n").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // integration smoke when `make artifacts` has run
        let dir = crate::runtime::default_artifacts_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("mlp").is_some());
            assert!(!m.tile_sizes().is_empty());
        }
    }
}
