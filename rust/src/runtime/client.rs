//! Compile-once PJRT executable cache.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see `/opt/xla-example/README.md`).

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::Manifest;

/// The L3 runtime: a PJRT CPU client plus compiled-executable cache over
/// the AOT artifact set.
pub struct Runtime {
    client: PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, PjRtLoadedExecutable>,
    /// Cumulative compile time (perf accounting).
    pub compile_time: Duration,
    /// Executions served.
    pub executions: u64,
}

impl Runtime {
    /// Create a runtime over an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            executables: HashMap::new(),
            compile_time: Duration::ZERO,
            executions: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the executable for an artifact.
    fn executable(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
            let start = Instant::now();
            let proto = HloModuleProto::from_text_file(&meta.path)
                .map_err(|e| anyhow!("parsing {}: {e}", meta.path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            self.compile_time += start.elapsed();
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Pre-compile an artifact (warm-up outside the serving hot path).
    pub fn warm(&mut self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute an artifact. All artifacts are lowered with
    /// `return_tuple=True`; this unwraps the tuple and returns its
    /// elements.
    pub fn run(&mut self, name: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        if args.len() != meta.arg_shapes.len() {
            anyhow::bail!(
                "{name}: want {} args, got {}",
                meta.arg_shapes.len(),
                args.len()
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        self.executions += 1;
        result
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e}"))
    }

    /// Convenience: run a 1-output artifact on f32 matrices, returning
    /// the flattened f32 output.
    pub fn run_f32(&mut self, name: &str, args: &[(&[f32], [u64; 2])]) -> Result<Vec<f32>> {
        let literals: Vec<Literal> = args
            .iter()
            .map(|(data, shape)| {
                Literal::vec1(data)
                    .reshape(&[shape[0] as i64, shape[1] as i64])
                    .map_err(|e| anyhow!("reshape to {shape:?}: {e}"))
            })
            .collect::<Result<_>>()?;
        let out = self.run(name, &literals)?;
        let first = out
            .into_iter()
            .next()
            .context("artifact returned empty tuple")?;
        first
            .to_vec::<f32>()
            .map_err(|e| anyhow!("result to f32: {e}"))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.executables.len())
            .field("executions", &self.executions)
            .finish()
    }
}
