//! Execution backends behind [`Runtime`].
//!
//! Two backends implement the artifact-execution contract:
//!
//! * **Native** (default): a pure-Rust interpreter of the known artifact
//!   computations — the tile kernel (`acc + A·B`), the full-GEMM
//!   artifacts, and the MLP forward chain (GEMM + ReLU per hidden
//!   layer), mirroring `python/compile/model.py` exactly. It keeps the
//!   crate dependency-light and the offline build green while producing
//!   real, verifiable numbers.
//! * **PJRT** (`--features pjrt`, requires the `xla` bindings crate —
//!   see `Cargo.toml` and DESIGN.md §Substitutions): compiles the AOT
//!   HLO **text** once per artifact on `xla::PjRtClient` and executes it
//!   with concrete buffers. Text, not serialized protos, is the
//!   interchange format: jax ≥ 0.5 emits 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::artifacts::Manifest;

/// The L3 runtime: an execution backend plus the artifact manifest it
/// serves, with compile-once caching (PJRT) and perf accounting.
pub struct Runtime {
    backend: Backend,
    manifest: Manifest,
    /// Cumulative compile time (zero for the native backend).
    pub compile_time: Duration,
    /// Executions served.
    pub executions: u64,
}

enum Backend {
    /// Pure-Rust interpreter of the artifact set.
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtState),
}

impl Runtime {
    /// Create a runtime over an artifacts directory. Uses the PJRT
    /// backend when the `pjrt` feature is enabled, the native
    /// interpreter otherwise.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        #[cfg(feature = "pjrt")]
        let backend = Backend::Pjrt(pjrt::PjrtState::new()?);
        #[cfg(not(feature = "pjrt"))]
        let backend = Backend::Native;
        Ok(Runtime {
            backend,
            manifest,
            compile_time: Duration::ZERO,
            executions: 0,
        })
    }

    /// A runtime over the native interpreter regardless of features —
    /// useful with [`Manifest::synthetic`] when no artifacts directory
    /// exists (tests, demos).
    pub fn native(manifest: Manifest) -> Self {
        Runtime {
            backend: Backend::Native,
            manifest,
            compile_time: Duration::ZERO,
            executions: 0,
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True when execution goes through the built-in native interpreter.
    /// The packed-panel engine (`runtime::PackedGemm`) short-circuits
    /// per-tile artifact dispatch in that case; PJRT keeps the per-call
    /// path so the real compiled kernel still runs.
    pub fn is_native(&self) -> bool {
        match &self.backend {
            Backend::Native => true,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => false,
        }
    }

    /// Account `n` kernel-equivalent executions performed outside
    /// [`Runtime::run_f32`]. The packed engine runs tile FMAs in-process
    /// without per-call dispatch; this keeps the perf counters truthful.
    pub fn note_executions(&mut self, n: u64) {
        self.executions += n;
    }

    /// Backend platform name (`native-cpu` or the PJRT platform).
    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Native => "native-cpu".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(state) => state.platform(),
        }
    }

    /// Pre-compile an artifact (warm-up outside the serving hot path).
    /// The native backend only checks the artifact exists.
    pub fn warm(&mut self, name: &str) -> Result<()> {
        if self.manifest.get(name).is_none() {
            bail!("unknown artifact {name:?}");
        }
        match &mut self.backend {
            Backend::Native => Ok(()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(state) => {
                let dt = state.compile(&self.manifest, name)?;
                self.compile_time += dt;
                Ok(())
            }
        }
    }

    /// Error unless `name` exists in the manifest and takes `got` args.
    fn arity_checked(&self, name: &str, got: usize) -> Result<()> {
        let want = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
            .arg_shapes
            .len();
        if got != want {
            bail!("{name}: want {want} args, got {got}");
        }
        Ok(())
    }

    /// Execute an artifact on raw XLA literals. All artifacts are
    /// lowered with `return_tuple=True`; this unwraps the tuple and
    /// returns its elements. PJRT-only: the native interpreter exposes
    /// the typed [`Runtime::run_f32`] instead.
    #[cfg(feature = "pjrt")]
    pub fn run(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.arity_checked(name, args.len())?;
        match &mut self.backend {
            Backend::Native => bail!("raw-literal execution needs the PJRT backend"),
            Backend::Pjrt(state) => {
                let (out, dt) = state.run(&self.manifest, name, args)?;
                self.compile_time += dt;
                self.executions += 1;
                Ok(out)
            }
        }
    }

    /// Convenience: run a 1-output artifact on f32 matrices, returning
    /// the flattened f32 output. Works on both backends.
    pub fn run_f32(&mut self, name: &str, args: &[(&[f32], [u64; 2])]) -> Result<Vec<f32>> {
        self.arity_checked(name, args.len())?;
        match &mut self.backend {
            Backend::Native => {
                let out = native_run_f32(name, args)?;
                self.executions += 1;
                Ok(out)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(state) => {
                let literals: Vec<xla::Literal> = args
                    .iter()
                    .map(|(data, shape)| {
                        xla::Literal::vec1(data)
                            .reshape(&[shape[0] as i64, shape[1] as i64])
                            .map_err(|e| anyhow!("reshape to {shape:?}: {e}"))
                    })
                    .collect::<Result<_>>()?;
                let (out, dt) = state.run(&self.manifest, name, &literals)?;
                self.compile_time += dt;
                self.executions += 1;
                let first = out
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("artifact returned empty tuple"))?;
                first
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("result to f32: {e}"))
            }
        }
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("artifacts", &self.manifest.artifacts.len())
            .field("executions", &self.executions)
            .finish()
    }
}

/// `c[j] += x · b[j]` — the axpy inner loop of every GEMM path here.
/// The body is split into exact 8-lanes (`chunks_exact`) so LLVM can
/// prove the trip count and emit packed FMA SIMD without a tail branch
/// in the hot body. Element order is unchanged: each `c[j]` receives
/// exactly one fused `+= x*b[j]`, so results are bit-identical to the
/// naive loop.
#[inline(always)]
pub(crate) fn axpy(c: &mut [f32], x: f32, b: &[f32]) {
    let n = c.len().min(b.len());
    let split = n - n % 8;
    let (c_body, c_tail) = c[..n].split_at_mut(split);
    let (b_body, b_tail) = b[..n].split_at(split);
    for (cc, bb) in c_body.chunks_exact_mut(8).zip(b_body.chunks_exact(8)) {
        for (cv, bv) in cc.iter_mut().zip(bb) {
            *cv += x * *bv;
        }
    }
    for (cv, bv) in c_tail.iter_mut().zip(b_tail) {
        *cv += x * *bv;
    }
}

/// `c += A · B` for one t×t block pair in the interpreter's row-major
/// layout (both operands row-major, `c` accumulated in place). Per
/// element, products are added in ascending-k order — the canonical
/// accumulation order every other kernel here must match.
#[inline]
pub(crate) fn tile_fma_rowmajor(c: &mut [f32], a: &[f32], b: &[f32], t: usize) {
    for (crow, arow) in c.chunks_exact_mut(t).zip(a.chunks_exact(t)) {
        for (&av, brow) in arow.iter().zip(b.chunks_exact(t)) {
            axpy(crow, av, brow);
        }
    }
}

/// `c += A · B` for one packed t×t block pair: `a` is k-major (the
/// packed A-panel layout — block column `kk` is contiguous) and `b` is
/// row-major, so each rank-1 update of the k-outer loop streams both
/// operands sequentially. Per element, products accumulate in
/// ascending-k order — bit-identical to [`tile_fma_rowmajor`].
#[inline]
pub(crate) fn tile_fma_kmajor(c: &mut [f32], a_kmajor: &[f32], b: &[f32], t: usize) {
    for (acol, brow) in a_kmajor.chunks_exact(t).zip(b.chunks_exact(t)) {
        for (crow, &av) in c.chunks_exact_mut(t).zip(acol) {
            axpy(crow, av, brow);
        }
    }
}

/// `c += A · B` for one packed t×t block pair, register-blocked: 4 rows
/// × `W` columns per micro-tile, so each reload of a B vector is reused
/// across four A scalars held in registers. Requires `t % 4 == 0` and
/// `t % W == 0` (checked by [`KernelKind::supports`]); every element
/// still receives exactly one `+= a·b` per k step, k ascending — the
/// same per-element operation sequence as [`tile_fma_kmajor`], so the
/// results are bit-identical (asserted by `tests/kernel_equivalence`).
#[inline(always)]
fn tile_fma_kmajor_blocked<const W: usize>(c: &mut [f32], a_kmajor: &[f32], b: &[f32], t: usize) {
    debug_assert!(t % 4 == 0 && t % W == 0);
    for (acol, brow) in a_kmajor.chunks_exact(t).zip(b.chunks_exact(t)) {
        for (cquad, aquad) in c.chunks_exact_mut(4 * t).zip(acol.chunks_exact(4)) {
            let (c0, rest) = cquad.split_at_mut(t);
            let (c1, rest) = rest.split_at_mut(t);
            let (c2, c3) = rest.split_at_mut(t);
            for (jw, bb) in brow.chunks_exact(W).enumerate() {
                let j = jw * W;
                for l in 0..W {
                    c0[j + l] += aquad[0] * bb[l];
                }
                for l in 0..W {
                    c1[j + l] += aquad[1] * bb[l];
                }
                for l in 0..W {
                    c2[j + l] += aquad[2] * bb[l];
                }
                for l in 0..W {
                    c3[j + l] += aquad[3] * bb[l];
                }
            }
        }
    }
}

/// Which micro-kernel computes a packed t×t block FMA. All variants
/// share the `acc + A·B` contract of [`tile_fma_kmajor`] — per C
/// element, one mul-then-add per k step in ascending-k order — so they
/// are interchangeable bit-for-bit; they differ only in how the loop
/// body is staged in registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// The generic kernel: rank-1 updates through [`axpy`], any tile
    /// size (tail handled per row).
    Scalar,
    /// 4-row × 4-column register micro-tiles; needs `t % 4 == 0`.
    Blocked4x4,
    /// 4-row × 8-column register micro-tiles (one full SIMD lane-group
    /// per column step on AVX2-class hardware); needs `t % 8 == 0`.
    Blocked4x8,
}

impl KernelKind {
    /// Short stable name for bench records and reports.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Blocked4x4 => "blocked4x4",
            KernelKind::Blocked4x8 => "blocked4x8",
        }
    }

    /// True when this kernel's alignment requirements hold for tile
    /// size `t` (the blocked kernels have no tail paths by design).
    pub fn supports(self, t: usize) -> bool {
        match self {
            KernelKind::Scalar => t > 0,
            KernelKind::Blocked4x4 => t > 0 && t % 4 == 0,
            KernelKind::Blocked4x8 => t > 0 && t % 8 == 0,
        }
    }

    /// `c += A · B` for one packed t×t block pair (`a` k-major, `b`
    /// row-major — the [`super::PackedGemm`] panel layout). Panics in
    /// debug builds if `t` violates [`KernelKind::supports`].
    #[inline]
    pub fn apply(self, c: &mut [f32], a_kmajor: &[f32], b: &[f32], t: usize) {
        debug_assert!(self.supports(t), "{} kernel with t={t}", self.name());
        match self {
            KernelKind::Scalar => tile_fma_kmajor(c, a_kmajor, b, t),
            KernelKind::Blocked4x4 => tile_fma_kmajor_blocked::<4>(c, a_kmajor, b, t),
            KernelKind::Blocked4x8 => tile_fma_kmajor_blocked::<8>(c, a_kmajor, b, t),
        }
    }
}

/// The kernel-selection table, keyed on tile size and alignment: the
/// widest register-blocked kernel whose alignment divides `t`. This is
/// the full table regardless of build features — use
/// [`selected_kernel`] for what a build actually dispatches.
pub fn kernel_table(t: usize) -> KernelKind {
    if t >= 8 && t % 8 == 0 {
        KernelKind::Blocked4x8
    } else if t >= 4 && t % 4 == 0 {
        KernelKind::Blocked4x4
    } else {
        KernelKind::Scalar
    }
}

/// The kernel [`super::PackedGemm`] dispatches for tile size `t` under
/// the current build features. The wide kernels are selected only with
/// `--features simd`; the default build keeps the historical scalar
/// path, byte-for-byte, so the two builds stay trivially comparable
/// (they are bit-identical either way — the feature gates risk, not
/// results).
pub fn selected_kernel(t: usize) -> KernelKind {
    #[cfg(feature = "simd")]
    {
        kernel_table(t)
    }
    #[cfg(not(feature = "simd"))]
    {
        let _ = t;
        KernelKind::Scalar
    }
}

/// Row-major f32 GEMM used by the native interpreter. Same i/k/j loop
/// nest (and therefore bit-identical results) as before, with the inner
/// loop routed through the vectorization-friendly [`axpy`].
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    debug_assert!(a.len() == m * k && b.len() == k * n);
    if m == 0 || k == 0 || n == 0 {
        return c;
    }
    for (crow, arow) in c.chunks_exact_mut(n).zip(a.chunks_exact(k)) {
        for (&av, brow) in arow.iter().zip(b.chunks_exact(n)) {
            axpy(crow, av, brow);
        }
    }
    c
}

/// Interpret one artifact natively (see the module docs for the
/// artifact-name → computation contract).
fn native_run_f32(name: &str, args: &[(&[f32], [u64; 2])]) -> Result<Vec<f32>> {
    if let Some(t) = name.strip_prefix("gemm_tile_") {
        let t: usize = t
            .parse()
            .map_err(|_| anyhow!("bad tile size in {name:?}"))?;
        // Guard against a manifest whose arity disagrees with the
        // interpreter's contract (the caller only checked the manifest).
        if args.len() != 3 {
            bail!("{name}: tile kernel takes acc, A, B (got {} args)", args.len());
        }
        if t == 0 {
            bail!("{name}: tile size must be positive");
        }
        let (acc, a, b) = (args[0].0, args[1].0, args[2].0);
        for (i, x) in [acc, a, b].iter().enumerate() {
            if x.len() != t * t {
                bail!("{name}: arg {i} len {} != {}", x.len(), t * t);
            }
        }
        let mut c = vec![0f32; t * t];
        tile_fma_rowmajor(&mut c, a, b, t);
        for (ci, &av) in c.iter_mut().zip(acc) {
            *ci += av;
        }
        return Ok(c);
    }
    if let Some(dims) = name.strip_prefix("gemm_full_") {
        let d: Vec<usize> = dims.split('x').filter_map(|v| v.parse().ok()).collect();
        let &[m, k, n] = d.as_slice() else {
            bail!("bad shape suffix in {name:?} (want gemm_full_MxKxN)");
        };
        if args.len() != 2 {
            bail!("{name}: full GEMM takes A, B (got {} args)", args.len());
        }
        let (a, b) = (args[0].0, args[1].0);
        if a.len() != m * k || b.len() != k * n {
            bail!("{name}: operand lengths do not match {m}x{k}x{n}");
        }
        return Ok(matmul(a, b, m, k, n));
    }
    if name == "mlp" {
        if args.len() < 2 {
            bail!("mlp: want input + weight matrices");
        }
        let (x, xs) = (args[0].0, args[0].1);
        let rows = xs[0] as usize;
        let mut cols = xs[1] as usize;
        if x.len() != rows * cols {
            bail!("mlp: input len {} != {rows}x{cols}", x.len());
        }
        let mut h = x.to_vec();
        let layers = args.len() - 1;
        for (wi, (w, ws)) in args[1..].iter().copied().enumerate() {
            let (wr, wc) = (ws[0] as usize, ws[1] as usize);
            if wr != cols || w.len() != wr * wc {
                bail!("mlp: weight {wi} shape {wr}x{wc} incompatible with {rows}x{cols}");
            }
            let mut out = matmul(&h, w, rows, cols, wc);
            if wi + 1 < layers {
                // hidden layers are ReLU; the classifier layer is linear
                for v in &mut out {
                    *v = v.max(0.0);
                }
            }
            h = out;
            cols = wc;
        }
        return Ok(h);
    }
    bail!(
        "artifact {name:?} is not supported by the native backend \
         (build with `--features pjrt` and real AOT artifacts)"
    )
}

#[cfg(feature = "pjrt")]
mod pjrt {
    //! The real PJRT backend: compile-once executable cache over
    //! `xla::PjRtClient`.

    use std::collections::HashMap;
    use std::time::{Duration, Instant};

    use anyhow::{anyhow, Result};
    use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

    use super::super::artifacts::Manifest;

    pub struct PjrtState {
        client: PjRtClient,
        executables: HashMap<String, PjRtLoadedExecutable>,
    }

    impl PjrtState {
        pub fn new() -> Result<Self> {
            let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
            Ok(PjrtState {
                client,
                executables: HashMap::new(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile once; returns the time spent compiling in this call
        /// (zero on a cache hit).
        pub fn compile(&mut self, manifest: &Manifest, name: &str) -> Result<Duration> {
            if self.executables.contains_key(name) {
                return Ok(Duration::ZERO);
            }
            let meta = manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
            let start = Instant::now();
            let proto = HloModuleProto::from_text_file(&meta.path)
                .map_err(|e| anyhow!("parsing {}: {e}", meta.path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            let dt = start.elapsed();
            self.executables.insert(name.to_string(), exe);
            Ok(dt)
        }

        /// Execute; returns the untupled outputs and any compile time
        /// spent on a cold executable.
        pub fn run(
            &mut self,
            manifest: &Manifest,
            name: &str,
            args: &[Literal],
        ) -> Result<(Vec<Literal>, Duration)> {
            let dt = self.compile(manifest, name)?;
            let exe = &self.executables[name];
            let result = exe
                .execute::<Literal>(args)
                .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
            let out = result
                .to_tuple()
                .map_err(|e| anyhow!("untupling result of {name}: {e}"))?;
            Ok((out, dt))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_tile_kernel_is_fma() {
        // 2×2: c = acc + a·b
        let acc = [1.0f32, 0.0, 0.0, 1.0];
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let c = native_run_f32(
            "gemm_tile_2",
            &[(&acc, [2, 2]), (&a, [2, 2]), (&b, [2, 2])],
        )
        .unwrap();
        assert_eq!(c, vec![20.0, 22.0, 43.0, 51.0]);
    }

    #[test]
    fn native_full_gemm_parses_shape_suffix() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let b = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0]; // 3×2
        let c = native_run_f32("gemm_full_2x3x2", &[(&a, [2, 3]), (&b, [3, 2])]).unwrap();
        assert_eq!(c, vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn native_mlp_applies_relu_on_hidden_layers_only() {
        // 1×2 input through two layers; first output is negative so the
        // hidden ReLU must clamp it, the final (linear) layer must not.
        let x = [1.0f32, 1.0];
        let w1 = [-1.0f32, 1.0, -1.0, 1.0]; // 2×2 -> [-2, 2] -> relu [0, 2]
        let w2 = [1.0f32, -1.0]; // 2×1 -> [-2]
        let out = native_run_f32("mlp", &[(&x, [1, 2]), (&w1, [2, 2]), (&w2, [2, 1])]).unwrap();
        assert_eq!(out, vec![-2.0]);
    }

    #[test]
    fn native_rejects_unknown_and_malformed() {
        assert!(native_run_f32("mystery", &[]).is_err());
        assert!(native_run_f32("gemm_tile_x", &[(&[], [0, 0]); 3]).is_err());
        let a = [0.0f32; 3];
        assert!(native_run_f32("gemm_tile_2", &[(&a, [2, 2]); 3]).is_err());
    }

    #[test]
    fn axpy_covers_body_and_tail() {
        // length 11 = one exact 8-lane + a 3-wide tail
        let mut c = vec![1.0f32; 11];
        let b: Vec<f32> = (0..11).map(|i| i as f32).collect();
        axpy(&mut c, 2.0, &b);
        for (j, v) in c.iter().enumerate() {
            assert_eq!(*v, 1.0 + 2.0 * j as f32);
        }
    }

    #[test]
    fn kmajor_kernel_matches_rowmajor_bit_for_bit() {
        let t = 5usize;
        let mut s = 77u64;
        let mut rand = || {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        };
        let a: Vec<f32> = (0..t * t).map(|_| rand()).collect();
        let b: Vec<f32> = (0..t * t).map(|_| rand()).collect();
        // transpose a into k-major
        let mut a_km = vec![0f32; t * t];
        for r in 0..t {
            for kk in 0..t {
                a_km[kk * t + r] = a[r * t + kk];
            }
        }
        let mut c_row = vec![0f32; t * t];
        tile_fma_rowmajor(&mut c_row, &a, &b, t);
        let mut c_km = vec![0f32; t * t];
        tile_fma_kmajor(&mut c_km, &a_km, &b, t);
        assert_eq!(c_row, c_km, "per-element accumulation order must agree");
    }

    #[test]
    fn kernel_table_keys_on_alignment() {
        assert_eq!(kernel_table(1), KernelKind::Scalar);
        assert_eq!(kernel_table(3), KernelKind::Scalar);
        assert_eq!(kernel_table(4), KernelKind::Blocked4x4);
        assert_eq!(kernel_table(12), KernelKind::Blocked4x4);
        assert_eq!(kernel_table(8), KernelKind::Blocked4x8);
        assert_eq!(kernel_table(16), KernelKind::Blocked4x8);
        assert_eq!(kernel_table(24), KernelKind::Blocked4x8);
        // every table entry satisfies its own alignment contract
        for t in 1..=64 {
            assert!(kernel_table(t).supports(t), "t={t}");
        }
        // the default build dispatches scalar; simd dispatches the table
        if cfg!(feature = "simd") {
            assert_eq!(selected_kernel(16), kernel_table(16));
        } else {
            assert_eq!(selected_kernel(16), KernelKind::Scalar);
        }
    }

    #[test]
    fn blocked_kernels_match_scalar_bit_for_bit() {
        let mut s = 42u64;
        let mut rand = || {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        };
        for t in [4usize, 8, 12, 16, 24, 32] {
            let a: Vec<f32> = (0..t * t).map(|_| rand()).collect();
            let b: Vec<f32> = (0..t * t).map(|_| rand()).collect();
            let mut want = vec![0f32; t * t];
            tile_fma_kmajor(&mut want, &a, &b, t);
            for kind in [KernelKind::Blocked4x4, KernelKind::Blocked4x8] {
                if !kind.supports(t) {
                    continue;
                }
                let mut got = vec![0f32; t * t];
                kind.apply(&mut got, &a, &b, t);
                assert_eq!(got, want, "{} t={t}", kind.name());
            }
        }
    }

    #[test]
    fn native_backend_is_native_and_notes_executions() {
        let mut rt = Runtime::native(Manifest::synthetic(&[2]));
        assert!(rt.is_native());
        rt.note_executions(5);
        assert_eq!(rt.executions, 5);
    }

    #[test]
    fn runtime_native_counts_executions() {
        let mut rt = Runtime::native(Manifest::synthetic(&[2]));
        assert_eq!(rt.platform(), "native-cpu");
        let z = [0.0f32; 4];
        rt.run_f32("gemm_tile_2", &[(&z, [2, 2]); 3]).unwrap();
        rt.run_f32("gemm_tile_2", &[(&z, [2, 2]); 3]).unwrap();
        assert_eq!(rt.executions, 2);
        assert_eq!(rt.compile_time, Duration::ZERO);
        // arity checked against the manifest
        assert!(rt.run_f32("gemm_tile_2", &[(&z, [2, 2]); 2]).is_err());
        assert!(rt.run_f32("gemm_tile_4", &[(&z, [2, 2]); 3]).is_err());
        assert!(rt.warm("gemm_tile_2").is_ok());
        assert!(rt.warm("nope").is_err());
    }
}
